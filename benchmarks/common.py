"""Shared benchmark utilities + v5e hardware model."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

# TPU v5e (the target platform for all modeled numbers)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link
VMEM_BYTES = 128 * 2 ** 20   # ~128 MB per core

# energy model constants (order-of-magnitude, documented in EXPERIMENTS.md):
# HBM access energy ~10 pJ/bit (HBM2e-class), MXU bf16 ~0.4 pJ/FLOP,
# on-chip SRAM ~1 pJ/bit.  Used only for the Table-V analogue.
E_HBM_PER_BYTE = 10e-12 * 8
E_FLOP = 0.4e-12
E_VMEM_PER_BYTE = 1e-12 * 8

# paper's GDN layer (Qwen3-Next config)
H_K = 16
H_V = 32
D_HEAD = 128
STATE_BYTES = H_V * D_HEAD * D_HEAD * 4          # 2 MB fp32
LAYER_FLOPS = H_V * (7 * D_HEAD * D_HEAD + 8 * D_HEAD)   # ~3.7 MFLOP


def timeit(fn, *args, iters=20, warmup=3):
    """Median wall time of a jitted callable (CPU measurement)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


_RESULTS = []


def emit(name: str, us_per_call: float, derived: str):
    """Print one CSV result line and record it for ``drain_results``
    (the machine-readable --json sink benchmarks build on)."""
    print(f"{name},{us_per_call:.3f},{derived}")
    _RESULTS.append({"name": name, "value": float(us_per_call),
                     "derived": derived})


def drain_results():
    """Return (and clear) every result ``emit`` recorded since the last
    drain — benchmarks call this per subcommand to group their JSON
    output."""
    out = list(_RESULTS)
    _RESULTS.clear()
    return out
