"""Serving-engine decode-block sweep: measure the host-sync overhead.

The engine fuses ``decode_block`` (k) decode+sample steps per tick into
one on-device ``lax.scan`` and syncs with the host once per block
(``lm.decode_steps``).  This benchmark sweeps k in {1, 4, 16} on the
reduced CPU configs and reports decode-only µs/token, so the per-token
host round-trip cost the device-resident loop removes is *measured*,
not asserted — µs/token should improve monotonically with k.

Each (arch, k) engine first serves a warm-up request so jit compilation
stays out of the measurement (``reset_metrics``).  Run with ``--quick``
for the CI smoke configuration (one arch, k in {1, 4}).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def _serve(eng, n_req: int, max_new: int):
    reqs = [Request(rid=i, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)


def run(quick: bool = False):
    archs = ("qwen3-next-gdn",) if quick else ("qwen3-next-gdn",
                                               "mamba2-1.3b")
    blocks = (1, 4) if quick else (1, 4, 16)
    max_new = 9 if quick else 17         # 1 admit token + k*ticks decode
    for arch in archs:
        cfg = configs.get_arch(arch).reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        for k in blocks:
            eng = DecodeEngine(cfg, params, max_slots=4, max_len=64,
                               decode_block=k)
            _serve(eng, 2, k + 1)        # warm-up: compile prefill + scan
            eng.reset_metrics()
            _serve(eng, 8, max_new)
            m = eng.metrics()
            emit(f"serving/{arch}/k{k}", m["decode_us_per_token"],
                 f"decode_block={k};decoded_tokens={m['decoded_tokens']};"
                 f"ticks={m['ticks']};mean_ttft_ms="
                 f"{m['mean_ttft_s'] * 1e3:.1f};slots=4;reduced_cpu")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke config: one arch, k in {1, 4}")
    args = ap.parse_args()
    run(quick=args.quick)
