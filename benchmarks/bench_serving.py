"""Beyond-paper benchmark: end-to-end serving engine throughput (CPU, reduced
configs) — exercises the persistent-state slot machinery the paper's §VIII
names as future work (batched multi-layer serving)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def run():
    for arch in ("qwen3-next-gdn", "mamba2-1.3b"):
        cfg = configs.get_arch(arch).reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        eng = DecodeEngine(cfg, params, max_slots=4, max_len=64)
        reqs = [Request(rid=i, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=8) for i in range(8)]
        for r in reqs:
            eng.submit(r)
        t0 = time.perf_counter()
        done = eng.run_until_done()
        dt = time.perf_counter() - t0
        toks = sum(len(r.output) for r in done)
        emit(f"serving/{arch}", dt / max(toks, 1) * 1e6,
             f"tokens={toks};ticks={eng.ticks};slots=4;reduced_cpu")


if __name__ == "__main__":
    run()
