"""Serving-engine benchmarks: host-sync overhead, TTFT under load and
cold-start compile cost.

All measurements run on the reduced CPU configs (absolute numbers are
CPU-interpreter scale; only the trend is the claim):

1. **decode-block sweep** — the engine fuses ``decode_block`` (k)
   decode+sample steps per tick into one on-device ``lax.scan`` and syncs
   with the host once per block (``lm.decode_steps``).  Sweeping k in
   {1, 4, 16} measures the per-token host round-trip cost the
   device-resident loop removes: µs/token should improve monotonically
   with k.

2. **TTFT under load** — requests are queued while every decode slot is
   busy with a long-budget request.  With ``overlap=False`` (the
   serialized baseline) a queued prompt prefills only after a slot frees,
   on the tick thread; with ``overlap=True`` it streams chunk-by-chunk
   into the staging buffer between decode ticks and emits its first token
   (fused on-device sample) *before* any slot frees.  The benchmark
   reports mean TTFT of the queued requests for both modes, asserts the
   overlapped mean is strictly better, and asserts the token streams are
   bitwise identical (overlap moves timing, never sampling).

3. **cold TTFT: masked vs pow2 chunk plans** — the first prompt a fresh
   engine serves pays jit tracing + XLA compilation for every program its
   chunk plan touches.  The masked planner dispatches at most TWO
   distinct prefill shapes per prompt (one scan + one fixed-size masked
   tail) where the pow2 baseline compiles a program per power-of-two
   tail sub-chunk, so cold TTFT (submit → first token device-confirmed,
   compiles included) drops with the program count.  The benchmark
   serves one awkward-length prompt on a fresh engine per mode
   (median-of-trials), reports both TTFTs, and asserts the masked
   planner's *prefill program count* is strictly smaller (the wall-clock
   is reported, not asserted — CI machines are noisy).

4. **burst prefill: batched vs per-prompt staging** — ``depth`` prompts
   arrive at once while every slot decodes.  The per-prompt path
   dispatches one chunk program per staged request per tick (O(depth)
   dispatches/tick); the batched packer fuses all staged prompts into
   one fixed-shape scan + one admit per tick (O(1), asserted at depth
   ∈ {1, 4, 8}).  At depth 8 the batched aggregate prefill throughput
   is asserted ≥ 1.5× the per-prompt baseline, with bitwise-identical
   token streams.

5. **slot oversubscription** — N interleaved sessions with idle gaps
   rotate through S << N slots via host-swapped state (pause/resume),
   once with synchronous paging and once with ``async_paging=True``.
   Token streams are asserted bitwise identical across both modes AND a
   dedicated-slot engine (one slot per session), per mixer kind with
   mixed greedy/stochastic sessions; swap µs/MiB is reported against
   the spec-derived per-slot byte budget, plus the swap-stall breakdown
   (gather / put / scatter µs per swap and the harvest overlap ratio).
   Async paging is asserted to spend measurably less blocked-host time
   per swap than the synchronous baseline, with overlap ratio > 0.

6. **mesh scaling** — (multi-device backends only, e.g.
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU) the
   engine's slot axis is data-parallel over the mesh: holding the
   per-device slot count fixed and growing the data axis grows tokens
   per tick at (ideally) constant tick latency.  The benchmark reports
   per-tick decode throughput at data ∈ {1, 4} and the speedup.  On
   real accelerators the speedup is asserted ≥ 1.5× at data=4; on CPU
   the "devices" are threads carved from the same cores, so the number
   is *reported as a measurement only* (documented in
   ``docs/serving.md`` — virtual devices share the host's FLOPs, which
   is exactly the situation the assertion would be meaningless in).

7. **speculative decode** — draft–verify with self-draft (acceptance ≈
   1, the upper bound) against a ``decode_block = k_draft``
   non-speculative baseline on the same mixed greedy/stochastic session
   set.  Streams are asserted bitwise identical and host syncs per
   emitted token strictly lower; acceptance rate and tokens/s are
   reported for both engines.

8. **disaggregated prefill/decode** — a mixed workload (long decode
   sessions + a storm of long-prompt prefill-only requests) served by
   two ``EngineWorker`` processes, once colocated (both workers serve
   both roles) and once disaggregated (one prefill worker pauses every
   request at the admit boundary and ships the swapped image to one
   decode worker).  Long-session decode throughput is measured with and
   without the concurrent storm; the storm-induced degradation is
   asserted *strictly lower* disaggregated than colocated (decode ticks
   never share an engine with prefill work), with all streams bitwise
   identical to a single-engine reference.

Each engine is built through ``make_engine``, which runs the warm-up
pass so jit compilation stays out of the measurement
(``reset_metrics``).  Run with ``--quick`` for the CI smoke
configuration, with a subcommand name (e.g. ``spec_decode``) to run one
benchmark, and with ``--json PATH`` to also write every emitted result
as per-subcommand machine-readable records.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro import configs
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def _serve(eng, n_req: int, max_new: int):
    reqs = [Request(rid=i, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=max_new) for i in range(n_req)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)


_ARCHES = {}


def arch_setup(arch: str):
    """Reduced-CPU config + randomly-initialised params for ``arch``,
    cached so every subcommand shares one init."""
    if arch not in _ARCHES:
        cfg = configs.get_arch(arch).reduced()
        _ARCHES[arch] = (cfg, lm.init_lm(jax.random.PRNGKey(0), cfg))
    return _ARCHES[arch]


def make_engine(cfg, params, *, warm: int = 0, warm_prompt=None,
                warm_new: int = 9, warm_paging: bool = False, **kw):
    """Build a ``DecodeEngine`` and run its warm-up pass so jit
    compilation stays out of the measurement.

    ``warm`` requests of ``warm_prompt`` (default: 8 tokens) with a
    ``warm_new`` budget compile every program the measured phase
    touches — the prompt's chunk plan, the tick buckets, admit and
    scatter, and on a speculative engine the draft / verify /
    draft-prefill programs as well.  ``warm_paging`` additionally
    round-trips one pause/resume so the state-gather and swap-in
    programs compile too.  Metrics are reset before returning."""
    eng = DecodeEngine(cfg, params, **kw)
    prompt = (np.arange(1, 9, dtype=np.int32) if warm_prompt is None
              else warm_prompt)
    if warm:
        for i in range(warm):
            eng.submit(Request(rid=10_000 + i, prompt=prompt,
                               max_new_tokens=warm_new))
        eng.run_until_done()
    if warm_paging:
        w = Request(rid=10_000 + warm, prompt=prompt,
                    max_new_tokens=warm_new)
        eng.submit(w)
        eng.step()
        eng.pause(w.rid)
        eng.step()      # a speculative engine swaps at the verify boundary
        eng.resume(w.rid)
        eng.run_until_done()
    eng.reset_metrics()
    return eng


def run_block_sweep(quick: bool = False):
    archs = ("qwen3-next-gdn",) if quick else ("qwen3-next-gdn",
                                               "mamba2-1.3b")
    blocks = (1, 4) if quick else (1, 4, 16)
    max_new = 9 if quick else 17         # 1 admit token + k*ticks decode
    for arch in archs:
        cfg, params = arch_setup(arch)
        for k in blocks:
            eng = make_engine(cfg, params, warm=2, warm_new=k + 1,
                              max_slots=4, max_len=64, decode_block=k)
            _serve(eng, 8, max_new)
            m = eng.metrics()
            emit(f"serving/{arch}/k{k}", m["decode_us_per_token"],
                 f"decode_block={k};decoded_tokens={m['decoded_tokens']};"
                 f"ticks={m['ticks']};mean_ttft_ms="
                 f"{m['mean_ttft_s'] * 1e3:.1f};slots=4;reduced_cpu")


def _ttft_load(cfg, params, *, overlap: bool, n_queued: int,
               trials: int):
    """Queued-admits-while-slots-decode scenario.

    Two long-budget requests (staggered completions) occupy both slots;
    the measured requests then queue behind them.  Serialized admit can
    only prefill a queued prompt once a slot frees; overlapped admit
    prefills it ahead of any free slot and emits its first token while
    both slots are still mid-decode.  Returns (median-of-``trials`` mean
    TTFT of the queued requests, token streams of the last trial) — the
    median keeps a single noisy CI run from polluting the comparison.
    """
    prompt = np.arange(1, 34, dtype=np.int32)            # 33 tokens
    # 3 warm-up requests also run a queued request through staging
    eng = make_engine(cfg, params, warm=3, warm_prompt=prompt,
                      max_slots=2, max_len=128, decode_block=4,
                      overlap=overlap, prefill_chunk=8)
    means = []
    for trial in range(trials):
        eng.reset_metrics()
        base = 1000 * trial
        load = [Request(rid=base + 100 + i, prompt=prompt,
                        max_new_tokens=48 + 20 * i) for i in range(2)]
        for r in load:
            eng.submit(r)
        eng.step()              # admit the load before the queued arrivals
        queued = [Request(rid=base + i, prompt=prompt, max_new_tokens=13)
                  for i in range(n_queued)]
        for r in queued:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in load + queued)
        means.append(float(np.mean([r.ttft_s for r in queued])))
        streams = [list(r.output) for r in load + queued]
    return float(np.median(means)), streams


def run_ttft_under_load(quick: bool = False):
    arch = "qwen3-next-gdn"
    n_queued = 2
    trials = 3 if quick else 5
    cfg, params = arch_setup(arch)
    serialized, s_streams = _ttft_load(cfg, params, overlap=False,
                                       n_queued=n_queued, trials=trials)
    overlapped, o_streams = _ttft_load(cfg, params, overlap=True,
                                       n_queued=n_queued, trials=trials)
    assert o_streams == s_streams, \
        "overlap must move timing only — token streams diverged"
    for mode, ttft in (("serialized", serialized),
                       ("overlapped", overlapped)):
        emit(f"serving/{arch}/ttft_load_{mode}", ttft * 1e6,
             f"mean_ttft_ms={ttft * 1e3:.1f};queued={n_queued};"
             f"trials={trials};slots=2;decode_block=4;prefill_chunk=8;"
             f"reduced_cpu")
    speedup = serialized / max(overlapped, 1e-12)
    emit(f"serving/{arch}/ttft_load_speedup", speedup,
         f"serialized_over_overlapped;bitwise_identical_streams")
    assert overlapped < serialized, (
        f"overlapped admit must beat the serialized baseline under load: "
        f"{overlapped * 1e3:.1f} ms >= {serialized * 1e3:.1f} ms")


def _cold_ttft(cfg, params, *, plan_mode: str, prompt_len: int,
               trials: int):
    """First-prompt TTFT on a fresh engine: tracing + compile + prefill.

    A fresh ``DeviceExecutor`` per trial means every prefill program in
    the prompt's chunk plan is compiled from scratch (jit caches key on
    the per-engine closures), which is exactly the cold-start cost the
    masked planner shrinks.  Returns (median TTFT s, prefill program
    count, token stream of the last trial)."""
    ttfts = []
    for trial in range(trials):
        eng = DecodeEngine(cfg, params, max_slots=2, max_len=128,
                           decode_block=4, prefill_chunk=8,
                           plan_mode=plan_mode)
        req = Request(rid=trial, prompt=np.arange(1, prompt_len + 1,
                                                  dtype=np.int32),
                      max_new_tokens=5)
        eng.submit(req)
        eng.run_until_done()
        ttfts.append(req.ttft_s)
        stream = list(req.output)
    progs = eng.executor.compiled_programs()["prefill"]
    return float(np.median(ttfts)), progs, stream


def run_cold_ttft(quick: bool = False):
    """Cold-TTFT comparison of the masked planner vs the pow2 baseline.

    77 tokens with chunk 8 is an awkward length: pow2 needs scan(4) +
    scan(1) + chunk(4) + admit(1) = 4 prefill programs, masked needs
    scan(3) + masked admit = 2."""
    arch = "qwen3-next-gdn"
    cfg, params = arch_setup(arch)
    trials = 3 if quick else 5
    results = {}
    for mode in ("pow2", "masked"):
        ttft, progs, stream = _cold_ttft(cfg, params, plan_mode=mode,
                                         prompt_len=77, trials=trials)
        results[mode] = (ttft, progs, stream)
        emit(f"serving/{arch}/cold_ttft_{mode}", ttft * 1e3,
             f"first_prompt_ttft_ms_incl_compiles;prefill_programs="
             f"{progs};prompt_len=77;prefill_chunk=8;trials={trials};"
             f"reduced_cpu")
    assert results["masked"][2] == results["pow2"][2], \
        "plan mode must move compile counts only — token streams diverged"
    assert results["masked"][1] < results["pow2"][1], (
        f"masked planning must compile strictly fewer prefill programs: "
        f"{results['masked'][1]} vs {results['pow2'][1]}")
    emit(f"serving/{arch}/cold_ttft_speedup",
         results["pow2"][0] / max(results["masked"][0], 1e-12),
         f"pow2_over_masked;prefill_programs_"
         f"{results['pow2'][1]}_vs_{results['masked'][1]}")


def _tick_throughput(cfg, params, *, data: int, slots_per_shard: int,
                     max_new: int, trials: int) -> float:
    """Decode-only tokens/s of one saturated engine at data-axis size
    ``data`` (slot count = data * slots_per_shard, all slots busy)."""
    slots = data * slots_per_shard
    mesh = mesh_mod.make_serving_mesh(data, 1) if data > 1 else None
    eng = make_engine(cfg, params, warm=slots, max_slots=slots,
                      max_len=64, decode_block=8, mesh=mesh)
    best = 0.0
    for _ in range(trials):
        eng.reset_metrics()
        _serve(eng, slots, max_new)            # every slot decodes
        m = eng.metrics()
        best = max(best, m["decoded_tokens"] / max(m["decode_s"], 1e-12))
    return best


def run_mesh_scaling(quick: bool = False):
    """Per-tick decode throughput vs the data-axis size (slot-axis DP).

    Needs >= 4 visible devices; under
    ``--xla_force_host_platform_device_count`` the devices are host
    threads, so the measured speedup is emitted but only *asserted* on
    real multi-device backends (see module docstring)."""
    if jax.device_count() < 4:
        emit("serving/mesh_scaling/skipped", 0.0,
             f"device_count={jax.device_count()}<4;set XLA_FLAGS="
             f"--xla_force_host_platform_device_count=8 for the CPU "
             f"smoke measurement")
        return
    arch = "qwen3-next-gdn"
    cfg, params = arch_setup(arch)
    trials = 2 if quick else 3
    max_new = 17 if quick else 33
    tput = {d: _tick_throughput(cfg, params, data=d, slots_per_shard=2,
                                max_new=max_new, trials=trials)
            for d in (1, 4)}
    for d, t in tput.items():
        emit(f"serving/{arch}/mesh_data{d}", t,
             f"decode_tokens_per_s;slots={2 * d};slots_per_shard=2;"
             f"decode_block=8;reduced_cpu_virtual_devices")
    speedup = tput[4] / max(tput[1], 1e-12)
    cpu_virtual = jax.default_backend() == "cpu"
    emit(f"serving/{arch}/mesh_scaling_speedup", speedup,
         f"data4_over_data1;asserted={not cpu_virtual};"
         f"{'cpu_virtual_devices_share_host_flops' if cpu_virtual else 'real_devices'}")
    if not cpu_virtual:
        assert speedup >= 1.5, (
            f"slot-axis DP must scale decode throughput on real devices: "
            f"data=4 gave {speedup:.2f}x over data=1 (< 1.5x)")


def _burst_prefill(cfg, params, *, depth: int, batching: bool,
                   trials: int):
    """Burst arrival under saturation: ``depth`` prompts submitted at
    once while both slots decode long budgets, stepped manually so every
    tick's staged-prefill dispatch count is observable.

    Returns (max prefill dispatches in any tick, median aggregate
    prefill throughput in prompt tokens/s from burst submission to the
    last first-token, token streams of the last trial)."""
    import time
    prompt = np.arange(1, 58, dtype=np.int32)          # 57 = 7 chunks + 1
    eng = make_engine(cfg, params, warm=depth + 2, warm_prompt=prompt,
                      max_slots=2, max_len=128, decode_block=4,
                      overlap=True, prefill_chunk=8,
                      staging_depth=depth, prefill_batching=batching)
    disp_max, tputs = 0, []
    for trial in range(trials):
        base = 1000 * (trial + 1)
        load = [Request(rid=base + 100 + i, prompt=prompt,
                        max_new_tokens=70 + 10 * i) for i in range(2)]
        for r in load:
            eng.submit(r)
        eng.step()              # both slots busy before the burst lands
        burst = [Request(rid=base + i, prompt=prompt, max_new_tokens=4)
                 for i in range(depth)]
        t0 = time.perf_counter()
        for r in burst:
            eng.submit(r)
        ticks = 0
        while any(r.t_first is None for r in burst):
            d0 = eng.stage_dispatches
            eng.step()
            disp_max = max(disp_max, eng.stage_dispatches - d0)
            ticks += 1
            assert ticks < 500, "burst prefill stalled"
        tputs.append(depth * len(prompt) / (time.perf_counter() - t0))
        eng.run_until_done()
        assert all(r.done for r in load + burst)
        streams = [list(r.output) for r in load + burst]
    return disp_max, float(np.median(tputs)), streams


def run_burst_prefill(quick: bool = False):
    """Batched multi-prompt prefill vs the per-prompt baseline under
    burst arrivals.

    The per-prompt path dispatches one chunk program per staged request
    per tick, so its dispatch count per tick grows linearly with the
    staging depth; the batched packer fuses every staged prompt into one
    fixed-shape scan + one admit program per tick — O(1) in queue depth
    (asserted at every depth).  Fewer, wider dispatches are also faster
    end to end: at depth 8 the batched aggregate prefill throughput
    (burst submission -> last first-token) is asserted >= 1.5x the
    per-prompt baseline, with bitwise-identical token streams."""
    arch = "qwen3-next-gdn"
    cfg, params = arch_setup(arch)
    trials = 2 if quick else 3
    tput = {}
    for depth in (1, 4, 8):
        res = {}
        for mode, batching in (("batched", True), ("per_prompt", False)):
            disp, tps, streams = _burst_prefill(
                cfg, params, depth=depth, batching=batching,
                trials=trials)
            res[mode] = (disp, tps, streams)
            emit(f"serving/{arch}/burst_prefill_{mode}_d{depth}", tps,
                 f"prompt_tokens_per_s;max_dispatches_per_tick={disp};"
                 f"depth={depth};prompt_len=57;prefill_chunk=8;slots=2;"
                 f"trials={trials};reduced_cpu")
        assert res["batched"][2] == res["per_prompt"][2], (
            f"depth={depth}: batching must move dispatch shapes only — "
            f"token streams diverged")
        # O(1) dispatches per tick: <= 1 fixed-shape scan + 1 admit
        # regardless of depth (the per-prompt path pays one dispatch per
        # staged request per tick)
        assert res["batched"][0] <= 2, (
            f"depth={depth}: batched packer dispatched "
            f"{res['batched'][0]} prefill programs in one tick")
        if depth >= 4:
            assert res["per_prompt"][0] >= depth // 2, (
                f"depth={depth}: per-prompt baseline no longer scales "
                f"with depth ({res['per_prompt'][0]} dispatches/tick) — "
                f"the comparison lost its contrast")
        tput[depth] = (res["batched"][1], res["per_prompt"][1])
    speedup = tput[8][0] / max(tput[8][1], 1e-12)
    emit(f"serving/{arch}/burst_prefill_speedup_d8", speedup,
         f"batched_over_per_prompt;bitwise_identical_streams")
    assert speedup >= 1.5, (
        f"batched prefill must beat the per-prompt baseline at depth 8: "
        f"{speedup:.2f}x < 1.5x")


_MIXERS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}


def _oversubscribe_rotate(cfg, params, *, n: int, slots: int,
                          make_sessions, **kw):
    """One oversubscribed rotation: every tick the engine reconnects the
    oldest parked session (a "client came back") and pauses the
    most-recently-activated resident (its "client went idle"), so
    sessions take repeated swap round-trips for as long as the workload
    runs.  Returns (token streams, metrics)."""
    from collections import deque
    eng = make_engine(cfg, params, warm_paging=True, max_slots=slots,
                      max_len=64, decode_block=2, prefill_chunk=8, **kw)
    live = make_sessions()
    for r in live:
        eng.submit(r)
    parked = deque()
    ticks = 0
    while not all(r.done for r in live):
        ticks += 1
        assert ticks < 3000, "oversubscribed rotation stalled"
        if parked:
            eng.resume(parked.popleft())    # oldest client reconnects
        if len(eng.active) > 1:
            # the newest resident goes idle mid-stream
            slot = max(eng.active,
                       key=lambda s: eng.active[s]._t_active)
            parked.append(eng.active[slot].rid)
            eng.pause(parked[-1])
        eng.step()
    while parked:
        eng.resume(parked.popleft())
    eng.run_until_done()
    assert all(r.done for r in live)
    return [list(r.output) for r in live], eng.metrics()


def run_oversubscribe(quick: bool = False):
    """Slot oversubscription: N interleaved sessions with idle gaps
    rotate through S << N device slots via host-swapped state — once
    synchronous, once with ``async_paging=True``.

    Token streams are asserted bitwise identical across sync paging,
    async paging AND a dedicated-slot engine with one slot per session —
    paging (and its overlap) moves placement and timing, never a token —
    for each mixer kind (all five when full, a recurrent + a KV-window
    kind under ``--quick``; per-kind async parity is also pinned by
    tests/test_state_paging.py), with mixed greedy/stochastic sessions.
    Reported: swap traffic and µs/MiB against the spec-derived per-slot
    byte budget (``cache_spec`` state + rolling window + sampler row),
    plus the swap-stall breakdown — gather / put / scatter µs per swap,
    blocked-host stall vs non-blocking dispatch time, and the harvest
    overlap ratio.  Asserted: async overlap ratio > 0 (sync is 0 by
    construction: every gather is force-harvested at dispatch) and async
    blocked-host stall per swap strictly below the synchronous
    baseline's."""
    kinds = ("gdn", "attn") if quick else tuple(_MIXERS)
    n, slots = (8, 2) if quick else (16, 4)

    def make_sessions():
        return [Request(rid=i,
                        prompt=np.arange(1, 6 + (i % 5) * 3,
                                         dtype=np.int32),
                        max_new_tokens=10 + (i % 4),
                        temperature=0.8 if i % 3 == 0 else 0.0,
                        top_k=10 if i % 3 == 0 else 0,
                        top_p=0.9 if i % 3 == 0 else 1.0)
                for i in range(n)]

    for kind in kinds:
        arch = _MIXERS[kind]
        cfg, params = arch_setup(arch)

        # dedicated-slot reference: every session keeps its own slot
        ded = DecodeEngine(cfg, params, max_slots=n, max_len=64,
                           decode_block=2, prefill_chunk=8)
        ref = make_sessions()
        for r in ref:
            ded.submit(r)
        ded.run_until_done()
        ref_streams = [list(r.output) for r in ref]

        res = {}
        for mode, apg in (("sync", False), ("async", True)):
            streams, m = _oversubscribe_rotate(
                cfg, params, n=n, slots=slots,
                make_sessions=make_sessions, async_paging=apg)
            assert streams == ref_streams, (
                f"{kind}/{mode}: oversubscription must be bitwise: "
                f"paging moves state, never a token")
            assert m["swap_outs"] >= n // 2, (
                f"{kind}/{mode}: rotation produced too little swap "
                f"traffic: {m['swap_outs']}")
            assert m["swap_ins"] == m["swap_outs"], \
                f"{kind}/{mode}: a parked session never resumed"
            res[mode] = m

            swaps = m["swap_outs"] + m["swap_ins"]
            stall_us = m["swap_stall_s"] / swaps * 1e6
            kib_slot = m["swap_bytes_per_slot"] / 2 ** 10
            emit(f"serving/{arch}/oversubscribe_swap_us_per_mb_{mode}",
                 m["swap_us_per_mb"],
                 f"slots={slots};sessions={n};swap_outs={m['swap_outs']};"
                 f"swap_mib={m['swap_bytes'] / 2 ** 20:.2f};"
                 f"kib_per_swap={kib_slot:.1f};bitwise_vs_dedicated;"
                 f"reduced_cpu")
            emit(f"serving/{arch}/oversubscribe_swap_stall_us_{mode}",
                 stall_us,
                 f"blocked_host_us_per_swap;swaps={swaps};"
                 f"dispatch_s={m['swap_dispatch_s']:.4f};"
                 f"stall_s={m['swap_stall_s']:.4f};"
                 f"gather_us_per_swap="
                 f"{m['swap_gather_s'] / swaps * 1e6:.1f};"
                 f"put_us_per_swap={m['swap_put_s'] / swaps * 1e6:.1f};"
                 f"scatter_us_per_swap="
                 f"{m['swap_scatter_s'] / swaps * 1e6:.1f};"
                 f"overlap_ratio={m['swap_overlap_ratio']:.3f};"
                 f"harvests_overlapped={m['swap_harvests_overlapped']};"
                 f"harvests_forced={m['swap_harvests_forced']};"
                 f"prefetch_hits={m['swap_prefetch_hits']}")

        sync_m, async_m = res["sync"], res["async"]
        assert sync_m["swap_overlap_ratio"] == 0.0, \
            f"{kind}: sync paging cannot overlap a harvest"
        assert async_m["swap_overlap_ratio"] > 0.0, (
            f"{kind}: async paging overlapped no harvest with the tick "
            f"({async_m['swap_harvests_forced']} forced)")
        sync_stall = sync_m["swap_stall_s"] / (sync_m["swap_outs"]
                                               + sync_m["swap_ins"])
        async_stall = async_m["swap_stall_s"] / (async_m["swap_outs"]
                                                 + async_m["swap_ins"])
        assert async_stall < sync_stall, (
            f"{kind}: async paging must lower blocked-host stall per "
            f"swap: {async_stall * 1e6:.1f} us >= "
            f"{sync_stall * 1e6:.1f} us")
        emit(f"serving/{arch}/oversubscribe_async_stall_reduction",
             sync_stall / max(async_stall, 1e-12),
             f"sync_over_async_blocked_host_us_per_swap;"
             f"sync_us={sync_stall * 1e6:.1f};"
             f"async_us={async_stall * 1e6:.1f};"
             f"overlap_ratio={async_m['swap_overlap_ratio']:.3f};"
             f"bitwise_identical_streams")


def run_spec_decode(quick: bool = False):
    """Speculative decode (self-draft) vs the non-speculative baseline.

    Both engines serve the same mixed greedy/stochastic session set; the
    baseline fuses ``decode_block = k_draft`` steps per tick (its best
    host-sync amortisation), the speculative engine drafts ``k_draft``
    and verifies, emitting up to ``k_draft + 1`` tokens per sync.  Token
    streams are asserted bitwise identical (the whole point of the
    shared-key verify) and, because self-draft acceptance is near 1,
    host syncs per emitted token are asserted *strictly lower* than the
    baseline's.  Reported: µs/token, tokens/s, acceptance rate,
    syncs/token for both engines."""
    arch = "qwen3-next-gdn"
    cfg, params = arch_setup(arch)
    k = 4
    n, max_new = (6, 13) if quick else (12, 25)
    slots = 2 if quick else 4

    def sessions():
        return [Request(rid=i,
                        prompt=np.arange(1, 6 + (i % 5) * 3,
                                         dtype=np.int32),
                        max_new_tokens=max_new - (i % 4),
                        temperature=0.8 if i % 3 == 0 else 0.0,
                        top_k=10 if i % 3 == 0 else 0,
                        top_p=0.9 if i % 3 == 0 else 1.0)
                for i in range(n)]

    res = {}
    for mode, spec in (("baseline", False), ("speculative", True)):
        eng = make_engine(cfg, params, warm=2, warm_new=k + 2,
                          max_slots=slots, max_len=64,
                          decode_block=k, speculative=spec, k_draft=k)
        reqs = sessions()
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        m = eng.metrics()
        res[mode] = ([list(r.output) for r in reqs], m)
        tps = m["decoded_tokens"] / max(m["decode_s"], 1e-12)
        emit(f"serving/{arch}/spec_decode_{mode}",
             m["decode_us_per_token"],
             f"decode_tokens_per_s={tps:.1f};"
             f"syncs_per_token={m['syncs_per_token']:.4f};"
             f"acceptance_rate={m['acceptance_rate']:.3f};"
             f"drafted={m['drafted_tokens']};"
             f"accepted={m['accepted_tokens']};k_draft={k};"
             f"slots={slots};sessions={n};self_draft;reduced_cpu")
    base_m, spec_m = res["baseline"][1], res["speculative"][1]
    assert res["speculative"][0] == res["baseline"][0], (
        "speculative decode must be bitwise: the shared-key verify "
        "emits exactly the non-speculative stream")
    assert spec_m["acceptance_rate"] > 0, "self-draft accepted nothing"
    assert spec_m["syncs_per_token"] < base_m["syncs_per_token"], (
        f"at acceptance {spec_m['acceptance_rate']:.2f} > 0, host syncs "
        f"per emitted token must strictly decrease: "
        f"{spec_m['syncs_per_token']:.4f} >= "
        f"{base_m['syncs_per_token']:.4f}")
    emit(f"serving/{arch}/spec_decode_sync_reduction",
         base_m["syncs_per_token"] / max(spec_m["syncs_per_token"],
                                         1e-12),
         f"baseline_syncs_per_token_over_speculative;"
         f"acceptance={spec_m['acceptance_rate']:.3f};"
         f"bitwise_identical_streams")


def _disagg_longs(n, max_new, rid0=0):
    """Long decode sessions (short prompt, long budget), mixed
    greedy/stochastic.  Streams depend only on (rid, sampler params,
    engine seed) — identical across topologies and phases."""
    return [Request(rid=rid0 + i, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=max_new,
                    temperature=0.8 if i % 2 == 0 else 0.0,
                    top_k=10 if i % 2 == 0 else 0,
                    top_p=0.9 if i % 2 == 0 else 1.0)
            for i in range(n)]


def _disagg_storm(cfg, n, plen, rid0=1000):
    """Prefill-only storm: long prompts with a 1-token budget — each
    request completes at the admit boundary (its single token is the
    fused admit sample), so it is pure staged-prefill load that never
    takes a slot and never hands off."""
    prompt = (np.arange(1, plen + 1) % (cfg.vocab - 2) + 1).astype(
        np.int32)
    return [Request(rid=rid0 + i, prompt=prompt, max_new_tokens=1)
            for i in range(n)]


def run_disagg(quick: bool = False):
    """Disaggregated prefill/decode over worker processes vs colocated.

    Two ``EngineWorker`` subprocesses behind the router, each with its
    own interpreter and jax runtime.  Colocated: both workers serve
    both roles, so every long decode session shares its engine's tick
    loop with storm prefill chunks.  Disaggregated: the prefill worker
    pauses every request at the admit boundary and the router ships the
    swapped image to the decode worker — storm chunks and decode ticks
    run in different processes.

    Per topology: phase A serves the long sessions alone (baseline
    throughput T0, mean per-request tokens/s over active time), phase B
    serves the same sessions under a concurrent prefill storm (T1).
    Degradation = T0/T1.  Asserted: every long-session stream (both
    topologies, both phases) is bitwise the single-engine reference
    stream; the prefill worker decodes zero tokens; disaggregated
    degradation is strictly below colocated.  Reported: T0, T1,
    degradation per topology and the colocated/disagg degradation
    ratio."""
    from repro.serving.engine import Router
    from repro.serving.rpc import EngineProxy

    arch = "qwen3-next-gdn"
    cfg, params = arch_setup(arch)
    n_long, max_new = (2, 24) if quick else (2, 48)
    n_storm, plen = (6, 96) if quick else (12, 96)
    kw = dict(max_slots=2, max_len=128, decode_block=2, prefill_chunk=8)

    # single-engine colocated reference: the bitwise target
    ref_eng = make_engine(cfg, params, **kw)
    ref = _disagg_longs(n_long, max_new)
    for r in ref:
        ref_eng.submit(r)
    ref_eng.run_until_done()
    ref_streams = [list(r.output) for r in ref]

    degradation = {}
    for mode, roles in (("colocated", ("both", "both")),
                        ("disagg", ("prefill", "decode"))):
        engines = [EngineProxy(cfg, params_seed=0, role=role, **kw)
                   for role in roles]
        router = Router(engines)
        # warm-up: compile every program the measured phases touch on
        # every worker (long-session chunk plan + decode on both, the
        # storm-length chunk plan on prefill-capable workers, and for
        # disagg the handoff gather/restore-scatter pair)
        warm = (_disagg_longs(2, 4, rid0=500)
                + _disagg_storm(cfg, 2, plen, rid0=700))
        for r in warm:
            router.submit(r)
        router.run_until_done()
        router.reset_metrics()

        streams = {}
        tps = {}
        for phase, stormy in (("unloaded", False), ("stormy", True)):
            longs = _disagg_longs(n_long, max_new)
            for r in longs:
                router.submit(r)
            storm = _disagg_storm(cfg, n_storm, plen) if stormy else []
            for r in storm:
                router.submit(r)
            router.run_until_done()
            assert all(r.done for r in longs + storm)
            streams[phase] = [list(r.output) for r in longs]
            assert streams[phase] == ref_streams, (
                f"{mode}/{phase}: disaggregated serving must be "
                f"bitwise: the handoff restores the exact admit-"
                f"boundary image")
            tps[phase] = float(np.mean([r.tokens_per_s for r in longs]))

        m = router.metrics()
        if mode == "disagg":
            assert m["handoffs"] >= n_long * 2, (
                f"disagg served {n_long * 2} long sessions but shipped "
                f"only {m['handoffs']} handoffs")
            assert m["per_engine"][0]["decoded_tokens"] == 0, (
                "the prefill worker must never run a decode tick")
        degradation[mode] = tps["unloaded"] / max(tps["stormy"], 1e-12)
        emit(f"serving/{arch}/disagg_decode_degradation_{mode}",
             degradation[mode],
             f"unloaded_tokens_per_s={tps['unloaded']:.2f};"
             f"stormy_tokens_per_s={tps['stormy']:.2f};"
             f"workers=2;roles={','.join(roles)};"
             f"long_sessions={n_long};storm={n_storm}x{plen}tok;"
             f"handoffs={m['handoffs']};"
             f"bitwise_vs_single_engine;reduced_cpu")
        for e in engines:
            e.shutdown()

    assert degradation["disagg"] < degradation["colocated"], (
        f"disaggregation must shield decode from prefill load: "
        f"degradation {degradation['disagg']:.3f}x (disagg) >= "
        f"{degradation['colocated']:.3f}x (colocated)")
    emit(f"serving/{arch}/disagg_degradation_ratio",
         degradation["colocated"] / max(degradation["disagg"], 1e-12),
         f"colocated_over_disagg_decode_degradation;"
         f"colocated={degradation['colocated']:.3f};"
         f"disagg={degradation['disagg']:.3f};"
         f"bitwise_identical_streams")


SUBCOMMANDS = {
    "block_sweep": run_block_sweep,
    "ttft_under_load": run_ttft_under_load,
    "cold_ttft": run_cold_ttft,
    "burst_prefill": run_burst_prefill,
    "oversubscribe": run_oversubscribe,
    "mesh_scaling": run_mesh_scaling,
    "spec_decode": run_spec_decode,
    "disagg": run_disagg,
}


def run(quick: bool = False, only=None, json_path=None):
    """Run ``only`` (a subcommand name) or every subcommand; with
    ``json_path``, write the ``emit`` records grouped per subcommand as
    machine-readable JSON (the ``BENCH_*.json`` artifact trajectory)."""
    from benchmarks.common import drain_results
    names = [only] if only else list(SUBCOMMANDS)
    drain_results()
    grouped = {}
    for name in names:
        SUBCOMMANDS[name](quick=quick)
        grouped[name] = drain_results()
    if json_path:
        import json
        with open(json_path, "w") as f:
            json.dump({"benchmark": "bench_serving",
                       "quick": bool(quick),
                       "subcommands": grouped}, f, indent=2)
        print(f"wrote {sum(len(v) for v in grouped.values())} results "
              f"to {json_path}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("subcommand", nargs="?", default=None,
                    choices=sorted(SUBCOMMANDS),
                    help="run one benchmark (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke config: one arch, k in {1, 4}, plus the "
                         "overlap-on/off TTFT-under-load comparison and "
                         "(4+ devices) the mesh-scaling measurement")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write per-subcommand machine-readable "
                         "results (name/value/derived records) to PATH")
    args = ap.parse_args()
    run(quick=args.quick, only=args.subcommand, json_path=args.json)
