"""Paper Table VI — resource utilization across design points.

FPGA BRAM/DSP/FF/LUT -> TPU analogue: VMEM working set and MXU issue
occupancy per gdn_decode head_block, plus the whole-model persistent-state
budget per assigned subquadratic arch (the 'does the state fit on chip'
precondition, Eq. 8)."""
from __future__ import annotations

from benchmarks.common import VMEM_BYTES, emit
from benchmarks.bench_table34_headblock import vmem_working_set
from repro import configs
from repro.core.intensity import arch_state_bytes, mixer_state_bytes


def run():
    for hb in (2, 4, 8, 16):
        ws = vmem_working_set(hb)
        emit(f"table6/vmem_head_block_{hb}", 0.0,
             f"vmem_kb={ws/1024:.0f};frac_of_vmem={ws/VMEM_BYTES:.4f};"
             f"paper_bram_frac={{2:0.12,4:0.25,8:0.25,16:0.25}}[{hb}]")
    # Eq. 8 precondition per arch: recurrent state per layer vs VMEM
    # (byte sizes come from the mixers' declarative cache specs)
    for name in ("qwen3-next-gdn", "mamba2-1.3b", "recurrentgemma-2b"):
        cfg = configs.get_arch(name)
        per_layer = arch_state_bytes(cfg) / max(
            1, sum(mixer_state_bytes(cfg, k) > 0 for k in cfg.layer_kinds))
        emit(f"table6/state_{name}", 0.0,
             f"state_per_layer_mb={per_layer/2**20:.2f};"
             f"fits_vmem={per_layer < VMEM_BYTES};"
             f"total_model_state_mb={arch_state_bytes(cfg)/2**20:.1f}")


if __name__ == "__main__":
    run()
