"""Paper Tables III+IV — head-level parallelism design space (H_iter sweep).

TPU analogue: the Pallas gdn_decode kernel's ``head_block`` (v-heads per
grid step).  For each head_block in {2, 4, 8, 16} we report:
  * VMEM working set of one grid step (the resource axis — Table VI role)
  * modeled per-token latency on v5e: the kernel streams the 2 MB state
    once each way; grid steps pipeline (Pallas double-buffers HBM<->VMEM),
    so latency ~ max(stream time, per-step compute) + pipeline fill
  * CPU wall-time of the interpret-mode kernel (correctness-path sanity,
    NOT a performance number)
plus the paper's own FPGA cycle model for comparison.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (HBM_BW, PEAK_FLOPS, STATE_BYTES, VMEM_BYTES,
                               H_K, H_V, D_HEAD, emit)


def vmem_working_set(hb: int) -> int:
    """One grid step: state block (in+out) + q/k/v slices + double buffer."""
    state_blk = hb * D_HEAD * D_HEAD * 4
    qkv = (2 * (hb // 2) + hb) * D_HEAD * 4 + 2 * hb * 4
    return 2 * state_blk + 2 * qkv          # x2: Pallas double buffering


def modeled_latency_us(hb: int) -> float:
    """v5e: one pass of 2 MB state each way, pipelined over Hv/hb steps."""
    n_steps = H_V // hb
    stream = 2 * STATE_BYTES / HBM_BW                    # read + write
    per_step_flops = hb * 7 * D_HEAD * D_HEAD
    compute = n_steps * per_step_flops / PEAK_FLOPS
    fill = (2 * STATE_BYTES / n_steps) / HBM_BW          # first block load
    return max(stream, compute) * 1e6 + fill * 1e6


def paper_fpga_model(h_iter: int) -> float:
    """Paper Eq. 12 @300 MHz: L = (32/H_iter) * 2106 cycles + T_load."""
    t_load_cycles = {2: 8800, 4: 9400, 8: 10554, 16: 10600}[h_iter]
    cycles = (H_V // h_iter) * 2106 + t_load_cycles
    return cycles * 3.33e-3                              # us @300 MHz


def run():
    from repro.kernels import ops, ref
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (1, H_K, D_HEAD))
    k = jax.random.normal(ks[1], (1, H_K, D_HEAD))
    v = jax.random.normal(ks[2], (1, H_V, D_HEAD))
    S = (jax.random.normal(ks[3], (1, H_V, D_HEAD, D_HEAD)) * 0.1)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (1, H_V)))
    b = jax.nn.sigmoid(jax.random.normal(ks[5], (1, H_V)))
    o_ref, S_ref = ref.gdn_decode_ref(q, k, v, S, g, b)

    for hb in (2, 4, 8, 16):
        o, S_new = ops.gdn_decode(q, k, v, S, g, b, head_block=hb)
        ok = bool(jnp.allclose(o, o_ref, rtol=2e-4, atol=2e-4))
        vmem = vmem_working_set(hb)
        lat = modeled_latency_us(hb)
        fpga = paper_fpga_model(hb)
        emit(f"table34/head_block_{hb}", lat,
             f"modeled_v5e_us={lat:.2f};vmem_kb={vmem/1024:.0f};"
             f"vmem_frac={vmem/VMEM_BYTES:.4f};paper_fpga_us={fpga:.1f};"
             f"allclose={ok}")

    # paper claim: all configs far below VMEM/BRAM limits; state streams at
    # full HBM bandwidth so head_block only moves the (tiny) fill term.
    emit("table34/note", 0.0,
         "tpu_state_streams_once_per_token;paper_optimum_Hiter8=63.2us;"
         "v5e_model_is_flat_because_HBM_stream_dominates")


if __name__ == "__main__":
    run()
