"""Generate the EXPERIMENTS.md §Roofline table from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.roofline_report [--dir experiments/dryrun]

Per (arch x shape) on the single-pod mesh: the three roofline terms in
seconds, the dominant term, MODEL_FLOPS/HLO_FLOPS, HBM fit, and a one-line
'what would move the dominant term' note.  Multi-pod rows prove the pod
axis shards (compile status only).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

MOVE_NOTES = {
    ("memory_s", "train"): "fuse attention scores in VMEM (Pallas flash "
                           "kernel) / bf16 the softmax residuals",
    ("memory_s", "prefill"): "chunkwise kernel keeps state+scores in VMEM; "
                             "larger KV blocks amortize cache writes",
    ("memory_s", "decode"): "state/KV traffic is the floor at batch-1 "
                            "(paper's premise); raise batch or shard state "
                            "further to cut per-chip bytes",
    ("collective_s", "train"): "reshard FFN to keep activations model-"
                               "sharded between layers; overlap grad "
                               "reduce-scatter with bwd compute",
    ("collective_s", "prefill"): "sequence-shard KV once and keep heads "
                                 "local; avoid re-gathering per layer",
    ("collective_s", "decode"): "batch the per-layer psums; decode "
                                "collectives are latency-bound (tiny)",
    ("compute_s", "train"): "MXU-align matmul tiles; drop remat on cheap "
                            "layers",
    ("compute_s", "prefill"): "MXU-align chunk size; widen chunk to raise "
                              "arithmetic intensity",
    ("compute_s", "decode"): "decode should never be compute-bound: check "
                             "for replicated compute",
}


def load(dir_):
    cells = {}
    for path in glob.glob(os.path.join(dir_, "*.json")):
        with open(path) as f:
            r = json.load(f)
        cells[(r["arch"], r["shape"], r["mesh"])] = r
    return cells


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def make_table(cells, mesh="single"):
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO flops | peak+args GB (16 limit) | multi-pod |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    archs = sorted({a for a, _, _ in cells})
    for arch in archs:
        for shape in SHAPE_ORDER:
            r = cells.get((arch, shape, mesh))
            if r is None:
                continue
            multi = cells.get((arch, shape, "multi"), {})
            mstat = multi.get("status", "—")
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | skipped | — "
                             f"| — | {mstat} |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | ERROR | | | | | "
                             f"| {mstat} |")
                continue
            rl = r["roofline"]
            mem = r["memory"]
            gb = (mem.get("peak_bytes", 0)
                  + mem.get("argument_bytes", 0)) / 1e9
            lines.append(
                f"| {arch} | {shape} | {fmt_s(rl['compute_s'])} "
                f"| {fmt_s(rl['memory_s'])} | {fmt_s(rl['collective_s'])} "
                f"| {rl['dominant'].replace('_s','')} "
                f"| {r['model_vs_hlo_flops']:.2f} | {gb:.1f} | {mstat} |")
    return "\n".join(lines)


def bottleneck_notes(cells):
    out = []
    for (arch, shape, mesh), r in sorted(cells.items()):
        if mesh != "single" or r["status"] != "ok":
            continue
        kind = ("train" if shape.startswith("train") else
                "prefill" if shape.startswith("prefill") else "decode")
        dom = r["roofline"]["dominant"]
        out.append(f"- **{arch} × {shape}**: dominant={dom.replace('_s','')}"
                   f" — {MOVE_NOTES.get((dom, kind), '')}")
    return "\n".join(out)


def state_table():
    """Per-arch persistent-state budget + batch-1 decode intensity, derived
    from the mixers' declarative cache specs (the same source of truth the
    model and serving engine are built on — no duplicated byte formulas)."""
    from repro import configs
    from repro.core import intensity
    lines = [
        "| arch | persistent state | decode intensity (HBM round-trip) "
        "| decode intensity (persistent) |",
        "|---|---|---|---|",
    ]
    for name in sorted(configs.ARCHS):
        cfg = configs.get_arch(name)
        sb = intensity.arch_state_bytes(cfg)
        rt = intensity.arch_decode_profile(cfg, persistent=False)
        ps = intensity.arch_decode_profile(cfg, persistent=True)
        lines.append(f"| {name} | {sb / 2**20:.2f} MiB "
                     f"| {rt.intensity:.2f} FLOP/B "
                     f"| {ps.intensity:.2f} FLOP/B |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--notes", action="store_true")
    ap.add_argument("--state", action="store_true",
                    help="print the spec-derived persistent-state table "
                         "(no dry-run JSONs needed)")
    args = ap.parse_args()
    if args.state:
        print(state_table())
        return
    cells = load(args.dir)
    print(make_table(cells))
    if args.notes:
        print()
        print(bottleneck_notes(cells))


if __name__ == "__main__":
    main()
