"""Paper Table II — per-token computational profile of one GDN layer
(h_v=32, d=128, FP32), GPU-style HBM round-trip vs persistent state.

Also *measures* the fused-vs-naive state-pass reduction structurally: the
decode step is lowered both ways and the state-touching traffic is read from
the compiled HLO (hlo_cost), confirming Alg. 2 touches the state exactly
once each way (2 passes) vs Alg. 1's three read passes + write."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (HBM_BW, LAYER_FLOPS, PEAK_FLOPS, STATE_BYTES,
                               H_K, H_V, D_HEAD, emit, timeit)
from repro.core import gdn, intensity
from repro.launch import hlo_cost


def analytic_rows():
    t2 = intensity.paper_table2()
    emit("table2/gpu_flops", 0.0, f"flops={t2['gpu']['flops']:.4g};"
                                  f"paper=4.2e6")
    emit("table2/gpu_state_io", 0.0,
         f"bytes={t2['gpu']['state_bytes']:.4g};paper_total_io=4.24e6")
    emit("table2/gpu_intensity", 0.0,
         f"flop_per_byte={t2['gpu']['intensity']:.3f};paper=1.0")
    emit("table2/ours_state_io", 0.0, "bytes=0;paper=0")
    emit("table2/ours_intensity", 0.0,
         f"flop_per_byte={t2['ours']['intensity']:.2f};paper=88")


def measured_state_traffic():
    """Lower naive (Alg 1) and fused (Alg 2) batched decode; count bytes."""
    B = 1
    q = jax.ShapeDtypeStruct((B, H_K, D_HEAD), jnp.float32)
    v = jax.ShapeDtypeStruct((B, H_V, D_HEAD), jnp.float32)
    S = jax.ShapeDtypeStruct((B, H_V, D_HEAD, D_HEAD), jnp.float32)
    g = jax.ShapeDtypeStruct((B, H_V), jnp.float32)

    def lower(fused):
        fn = lambda q, k, v, S, g, b: gdn.gdn_decode(      # noqa: E731
            q, k, v, S, g, b, fused=fused)
        return jax.jit(fn).lower(q, q, v, S, g, g).compile().as_text()

    naive = hlo_cost.analyze(lower(False))
    fused = hlo_cost.analyze(lower(True))
    emit("table2/naive_hlo_bytes", 0.0,
         f"bytes={naive['bytes']:.4g};state=2MB*4passes~8.4e6")
    emit("table2/fused_hlo_bytes", 0.0,
         f"bytes={fused['bytes']:.4g};state=2MB*2passes~4.2e6")
    ratio = naive["bytes"] / max(fused["bytes"], 1)
    emit("table2/fused_traffic_reduction", 0.0,
         f"naive_over_fused={ratio:.2f};paper_cycle_ratio=1.46")
    return ratio


def measured_walltime():
    """CPU wall time, batch-1 paper layer: fused vs naive (both memory-bound
    on CPU too, so the pass-count reduction is directly visible)."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (1, H_K, D_HEAD))
    k = jax.random.normal(ks[1], (1, H_K, D_HEAD))
    v = jax.random.normal(ks[2], (1, H_V, D_HEAD))
    S = jax.random.normal(ks[3], (1, H_V, D_HEAD, D_HEAD)) * 0.1
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (1, H_V)))

    naive = jax.jit(lambda *a: gdn.gdn_decode(*a, fused=False))
    fused = jax.jit(lambda *a: gdn.gdn_decode(*a, fused=True))
    t_naive = timeit(naive, q, k, v, S, g, g) * 1e6
    t_fused = timeit(fused, q, k, v, S, g, g) * 1e6
    emit("table2/naive_decode_cpu", t_naive, "alg1_3pass")
    emit("table2/fused_decode_cpu", t_fused,
         f"alg2_2pass;speedup={t_naive / t_fused:.2f}x")


def modeled_tpu_latency():
    """v5e per-layer decode-step latency model (the TPU analogue of the
    paper's Eq. 12): memory-bound term dominates at batch 1."""
    t_mem_naive = 4 * STATE_BYTES / HBM_BW       # 3 reads + 1 write
    t_mem_fused = 2 * STATE_BYTES / HBM_BW       # 1 read + 1 write
    t_compute = LAYER_FLOPS / PEAK_FLOPS
    emit("table2/v5e_naive_layer_us", t_mem_naive * 1e6,
         f"modeled;compute_us={t_compute*1e6:.3f}")
    emit("table2/v5e_fused_layer_us", t_mem_fused * 1e6,
         f"modeled;speedup={t_mem_naive/t_mem_fused:.2f}x;"
         f"paper_fpga_us_per_layer={63.2}")


def run():
    analytic_rows()
    measured_state_traffic()
    measured_walltime()
    modeled_tpu_latency()


if __name__ == "__main__":
    run()
