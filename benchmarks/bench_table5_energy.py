"""Paper Table V — per-token energy comparison (modeled).

The paper measures 9.96 W on the placed FPGA and 99.8 mJ/token on the GPU
reference.  Without hardware we report a transparent energy MODEL for the
paper's single GDN layer at batch 1 (constants in benchmarks/common.py):

  E = HBM_bytes * e_hbm + FLOPs * e_flop + VMEM_bytes * e_vmem

for (a) GPU-style HBM round-trip decode, (b) TPU fused decode (state
streamed once each way), (c) the paper's FPGA numbers verbatim for
reference.  The claim being reproduced is the *ordering and scale*: removing
state round-trips is worth ~2x energy, and the paper's full-persistence adds
the rest of its 62x via low static power — not reachable by a von-Neumann
accelerator model and noted as such."""
from __future__ import annotations

from benchmarks.common import (E_FLOP, E_HBM_PER_BYTE, E_VMEM_PER_BYTE,
                               LAYER_FLOPS, STATE_BYTES, emit)

TOKEN_IO = 48.5e3     # paper: ~48.5 KB per token


def run():
    flops = LAYER_FLOPS
    # (a) GPU-style: 3 state reads + 1 write + token IO through HBM
    e_gpu = (4 * STATE_BYTES + TOKEN_IO) * E_HBM_PER_BYTE + flops * E_FLOP
    # (b) TPU fused: 1 read + 1 write + token IO
    e_tpu = (2 * STATE_BYTES + TOKEN_IO) * E_HBM_PER_BYTE + flops * E_FLOP
    # (c) idealized persistence: state never leaves on-chip SRAM
    e_persist = (TOKEN_IO * E_HBM_PER_BYTE + flops * E_FLOP
                 + 2 * STATE_BYTES * E_VMEM_PER_BYTE)
    emit("table5/gpu_roundtrip_uJ", 0.0, f"energy_uJ={e_gpu*1e6:.2f}")
    emit("table5/tpu_fused_uJ", 0.0,
         f"energy_uJ={e_tpu*1e6:.2f};vs_gpu={e_gpu/e_tpu:.2f}x")
    emit("table5/persistent_ideal_uJ", 0.0,
         f"energy_uJ={e_persist*1e6:.2f};vs_gpu={e_gpu/e_persist:.2f}x")
    emit("table5/paper_reference", 0.0,
         "fpga_1.61mJ_full_model_token;gpu_99.8mJ;62x;"
         "note=paper numbers are full-token wall-power, model is per-layer "
         "dynamic energy — ordering reproduced, magnitude not comparable")


if __name__ == "__main__":
    run()
