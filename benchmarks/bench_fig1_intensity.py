"""Paper Fig. 1 — batch-1 decode arithmetic intensity across architectures.

Reproduces the paper's central observation: all subquadratic sequence models
sit BELOW softmax attention on the decode roofline (< 1 FLOP/B), and the
persistent-state design lifts GDN to ~88 FLOP/B.  Extended beyond the paper
to every assigned architecture's mixer."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import intensity


def mixer_rows():
    rows = [
        ("fig1/mhsa_gqa_seq4k", intensity.gqa_profile(
            h_q=32, h_kv=8, d=128, seq=4096)),
        ("fig1/gdn_hbm_roundtrip", intensity.gdn_profile(
            persistent=False, fused=False)),
        ("fig1/gdn_fused_hbm", intensity.gdn_profile(
            persistent=False, fused=True)),
        ("fig1/gdn_persistent_ours", intensity.gdn_profile(persistent=True)),
        ("fig1/mamba2_hbm", intensity.mamba2_profile()),
        ("fig1/mamba2_persistent", intensity.mamba2_profile(persistent=True)),
        ("fig1/rglru_hbm", intensity.rglru_profile()),
        # assigned archs' attention mixers at decode (per-layer):
        ("fig1/minicpm_mha_4k", intensity.gqa_profile(36, 36, 64, 4096, 2)),
        ("fig1/yi9b_gqa_32k", intensity.gqa_profile(32, 4, 128, 32768, 2)),
        ("fig1/danube_swa_win4k", intensity.gqa_profile(32, 8, 80, 4096, 2)),
        ("fig1/musicgen_mha_4k", intensity.gqa_profile(24, 24, 64, 4096, 2)),
    ]
    return rows


def run():
    for name, prof in mixer_rows():
        emit(name, 0.0, f"intensity_flop_per_byte={prof.intensity:.3f};"
                        f"flops={prof.flops:.3g};bytes={prof.total_bytes:.3g}")
    # the paper's qualitative claims, checked programmatically:
    gqa = intensity.gqa_profile().intensity
    gdn = intensity.gdn_profile(persistent=False, fused=False).intensity
    ours = intensity.gdn_profile(persistent=True).intensity
    assert gdn < 1.0 and gdn < gqa * 1.5, "GDN must be memory-bound vs GQA"
    assert ours > 50, "persistent state must make GDN compute-bound"
    emit("fig1/claim_check", 0.0,
         f"gqa={gqa:.2f};gdn={gdn:.2f};ours={ours:.1f};paper_gdn=0.87;"
         f"paper_ours=88")


if __name__ == "__main__":
    run()
