"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (0.0 us for analytic rows).

  Fig. 1    -> bench_fig1_intensity   (decode arithmetic intensity)
  Table II  -> bench_table2_profile   (per-token profile, fused vs naive)
  Tables III/IV -> bench_table34_headblock (head_block design sweep)
  Table V   -> bench_table5_energy    (modeled energy per token)
  Table VI  -> bench_table6_resources (VMEM/state-fit budget)
  extra     -> bench_serving          (continuous-batching engine)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_fig1_intensity, bench_table2_profile,
                            bench_table34_headblock, bench_table5_energy,
                            bench_table6_resources, bench_serving)
    mods = [bench_fig1_intensity, bench_table2_profile,
            bench_table34_headblock, bench_table5_energy,
            bench_table6_resources, bench_serving]
    print("name,us_per_call,derived")
    failed = 0
    for mod in mods:
        try:
            mod.run()
        except Exception:            # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{mod.__name__},nan,ERROR")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
