"""Mamba-2 (SSD) mixer — the attention-free assigned architecture.

Decode shares the paper's persistent-state structure: per head h a state
S^(h) in R^{d_state x d_head} updated as S <- g*S + B x^T with output
y = S^T C — i.e. the GDN recurrence *without* the delta rule
(`delta_rule=False` in the shared kernels; see DESIGN.md §Arch-applicability).

Projections are kept separate (w_z / w_x / w_B / w_C / w_dt) so tensor
parallelism shards the per-head quantities (x, dt, heads) on the model axis
while the head-shared B/C (n_groups=1 — the SSM analogue of MQA) stay
replicated, Megatron-Mamba style.  Causal conv(4) applies depthwise to x,
B and C with separate filters (equivalent to the fused xBC conv).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gdn as gdn_core
from repro.models import layers

# causal-conv width (fixed, as in Mamba-2); the mixer registry's cache_spec
# must describe carries of exactly this width
CONV_WIDTH = 4


class SSMState(NamedTuple):
    S: jax.Array          # (B, nheads, d_state, headdim) fp32
    conv_x: jax.Array     # (B, conv_width-1, d_inner)
    conv_B: jax.Array     # (B, conv_width-1, d_state)
    conv_C: jax.Array     # (B, conv_width-1, d_state)


def init_ssm(key, d_model, d_inner, headdim, d_state,
             conv_width=CONV_WIDTH,
             dtype=jnp.float32):
    nheads = d_inner // headdim
    ks = jax.random.split(key, 9)
    s = d_model ** -0.5
    return {
        "w_z": (jax.random.normal(ks[0], (d_model, d_inner)) * s).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d_model, d_inner)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (d_model, d_state)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (d_model, d_state)) * s).astype(dtype),
        "w_dt": (jax.random.normal(ks[4], (d_model, nheads)) * s).astype(dtype),
        "conv_x": layers.init_conv1d(ks[5], d_inner, conv_width, dtype),
        "conv_B": layers.init_conv1d(ks[6], d_state, conv_width, dtype),
        "conv_C": layers.init_conv1d(ks[7], d_state, conv_width, dtype),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "dt_bias": jnp.full((nheads,), 0.5, jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm": layers.init_rmsnorm(d_inner),
        "out_proj": (jax.random.normal(ks[8], (d_inner, d_model))
                     * (d_inner ** -0.5)).astype(dtype),
    }


def _silu(x):
    return jax.nn.silu(x.astype(jnp.float32)).astype(x.dtype)


def _ssd_terms(p, x_in, B_in, C_in, dt, headdim):
    """Post-conv activations -> kernel inputs. Shapes (..., nheads, hd) etc."""
    nheads = p["A_log"].shape[0]
    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    log_g = -jnp.exp(p["A_log"]) * dt_s                   # (..., nheads)
    xh = x_in.reshape(*x_in.shape[:-1], nheads, headdim)
    v = (xh.astype(jnp.float32) * dt_s[..., None]).astype(x_in.dtype)
    return xh, v, log_g


def _out(p, y, z, xh, x_dtype):
    d_shape = (1,) * (y.ndim - 2) + (p["D"].shape[0], 1)
    y = y + p["D"].reshape(d_shape) * xh.astype(y.dtype)
    y = y.reshape(*y.shape[:-2], -1)
    y = layers.rmsnorm_fwd(p["norm"], y.astype(x_dtype))
    y = y * _silu(z)
    return layers.dot(y, p["out_proj"])


def ssm_train(p, x, *, d_inner, headdim, d_state, chunk=64):
    """Full-sequence SSD via the shared chunkwise path (delta_rule=False)."""
    B, T, _ = x.shape
    nheads = d_inner // headdim
    z = layers.dot(x, p["w_z"])
    xi = _silu(layers.conv1d_fwd(p["conv_x"], layers.dot(x, p["w_x"])))
    Bi = _silu(layers.conv1d_fwd(p["conv_B"], layers.dot(x, p["w_B"])))
    Ci = _silu(layers.conv1d_fwd(p["conv_C"], layers.dot(x, p["w_C"])))
    dt = layers.dot(x, p["w_dt"])
    xh, v, log_g = _ssd_terms(p, xi, Bi, Ci, dt, headdim)
    S0 = jnp.zeros((B, nheads, d_state, headdim), jnp.float32)
    O, _ = gdn_core.gdn_prefill(
        Ci[:, :, None, :].astype(jnp.float32),
        Bi[:, :, None, :].astype(jnp.float32),
        v.astype(jnp.float32), log_g, jnp.ones_like(log_g), S0,
        chunk=chunk, delta_rule=False)
    return _out(p, O.astype(x.dtype), z, xh, x.dtype)


def _conv_prefill(conv_p, u, cache, valid_len=None):
    """Seeded causal conv; returns (activated output, new cache tail).

    With ``valid_len`` set, the returned carry is the last ``w - 1``
    *valid* inputs (rows ``[valid_len, valid_len + w - 1)`` of
    cache‖u) — the carry serial decode would hold after the valid
    prefix, not the padded garbage at the block's end.  ``valid_len`` may
    be a per-row (B,) vector (the batched staging path): each row's carry
    is gathered at its own boundary; a scalar keeps the
    ``dynamic_slice`` path bitwise-unchanged.
    """
    T = u.shape[1]
    w = conv_p["w"].shape[0]
    full = jnp.concatenate([cache.astype(u.dtype), u], axis=1)
    out = layers.conv1d_fwd(conv_p, full)[:, -T:, :]
    if valid_len is None:
        tail = full[:, -(w - 1):, :]
    else:
        vl = jnp.asarray(valid_len, jnp.int32)
        if vl.ndim == 0:
            tail = jax.lax.dynamic_slice_in_dim(full, vl, w - 1, axis=1)
        else:
            idx = vl[:, None] + jnp.arange(w - 1)[None, :]   # (B, w-1)
            tail = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    return _silu(out), tail


def ssm_prefill(p, x, state: SSMState, *, d_inner, headdim, d_state,
                chunk=64, use_pallas=False, valid_len=None):
    B, T, _ = x.shape
    z = layers.dot(x, p["w_z"])
    xi, cx = _conv_prefill(p["conv_x"], layers.dot(x, p["w_x"]),
                           state.conv_x, valid_len)
    Bi, cB = _conv_prefill(p["conv_B"], layers.dot(x, p["w_B"]),
                           state.conv_B, valid_len)
    Ci, cC = _conv_prefill(p["conv_C"], layers.dot(x, p["w_C"]),
                           state.conv_C, valid_len)
    dt = layers.dot(x, p["w_dt"])
    xh, v, log_g = _ssd_terms(p, xi, Bi, Ci, dt, headdim)
    ones = jnp.ones_like(log_g)
    if use_pallas:
        from repro.kernels import ops
        O, S = ops.gdn_prefill(
            Ci[:, :, None, :], Bi[:, :, None, :], v, log_g,
            ones, state.S, chunk=chunk, delta_rule=False,
            valid_len=valid_len)
    else:
        Bk, vk, log_gk = Bi[:, :, None, :], v, log_g
        if valid_len is not None:
            from repro.models.gdn_layer import mask_ragged_inputs
            Bk, vk, log_gk, ones = mask_ragged_inputs(valid_len, Bk, vk,
                                                      log_gk, ones)
        O, S = gdn_core.gdn_prefill(
            Ci[:, :, None, :].astype(jnp.float32),
            Bk.astype(jnp.float32),
            vk.astype(jnp.float32), log_gk, ones,
            state.S.astype(jnp.float32), chunk=chunk, delta_rule=False)
        S = S.astype(state.S.dtype)
    out = _out(p, O.astype(x.dtype), z, xh, x.dtype)
    return out, SSMState(S=S, conv_x=cx.astype(state.conv_x.dtype),
                         conv_B=cB.astype(state.conv_B.dtype),
                         conv_C=cC.astype(state.conv_C.dtype))


def ssm_decode(p, x_t, state: SSMState, *, d_inner, headdim, d_state,
               use_pallas=False, head_block=8):
    """One-token decode via the fused persistent-state kernel path."""
    z = layers.dot(x_t, p["w_z"])
    xi, cx = layers.conv1d_decode(p["conv_x"], layers.dot(x_t, p["w_x"]),
                                  state.conv_x)
    Bi, cB = layers.conv1d_decode(p["conv_B"], layers.dot(x_t, p["w_B"]),
                                  state.conv_B)
    Ci, cC = layers.conv1d_decode(p["conv_C"], layers.dot(x_t, p["w_C"]),
                                  state.conv_C)
    xi, Bi, Ci = _silu(xi), _silu(Bi), _silu(Ci)
    dt = layers.dot(x_t, p["w_dt"])
    xh, v, log_g = _ssd_terms(p, xi, Bi, Ci, dt, headdim)
    g = jnp.exp(log_g)
    ones = jnp.ones_like(g)
    if use_pallas:
        from repro.kernels import ops
        o, S = ops.gdn_decode(Ci[:, None, :], Bi[:, None, :], v,
                              state.S, g, ones, head_block=head_block,
                              delta_rule=False)
    else:
        o, S = gdn_core.gdn_decode(
            Ci[:, None, :].astype(jnp.float32),
            Bi[:, None, :].astype(jnp.float32),
            v.astype(jnp.float32), state.S.astype(jnp.float32), g, ones,
            fused=True, delta_rule=False)
        S = S.astype(state.S.dtype)
    out = _out(p, o.astype(x_t.dtype), z, xh, x_t.dtype)
    return out, SSMState(S=S, conv_x=cx, conv_B=cB, conv_C=cC)
