"""Mixture-of-Experts FFN (GShard/Switch-style capacity dispatch, EP-shardable).

Top-k softmax router with grouped one-hot dispatch: tokens are processed in
groups of ``group_size`` with a per-group expert capacity
C = group_size * k * cf / E.  Dispatch/combine einsum overhead relative to
expert FLOPs is E*C/(3*d_ff) = group_size*k*cf/(3*d_ff) — group_size is
chosen per-arch to keep this under ~25% (mixtral: 1024 -> 6%, arctic-480b:
1024 -> 18%).  GSPMD lowers expert parallelism to all-to-alls when the
expert dim of the weights is sharded on the `model` axis and tokens on
`data`.

Decode uses a dense all-expert einsum: at serving batch sizes every expert
is hit with near-certainty, so weight *traffic* (the roofline term that
dominates decode) is identical to a gather-based dispatch, with no dynamic
shapes.  Load-balancing auxiliary loss included for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_moe(key, d_model, d_ff, n_experts, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    sf = d_ff ** -0.5
    return {
        "router": (jax.random.normal(ks[0], (d_model, n_experts)) * s
                   ).astype(jnp.float32),
        "wi_gate": (jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * s
                    ).astype(dtype),
        "wi_up": (jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * s
                  ).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * sf
               ).astype(dtype),
    }


def moe_fwd(p, x, *, top_k=2, capacity_factor=1.25, group_size=1024):
    """x: (B, T, d) -> (y (B, T, d), aux_loss scalar)."""
    B, T, d = x.shape
    E = p["router"].shape[1]
    N = B * T
    S = min(group_size, N)
    assert N % S == 0, (N, S)
    G = N // S
    C = max(1, int(S * top_k * capacity_factor / E))

    xf = x.reshape(G, S, d)
    logits = jnp.einsum("gsd,de->gse", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)              # (G, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)

    # ----- load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                         # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # ----- queue position of each (token, slot) within its expert, per group
    onehot_i = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (G, S, k, E)
    flat = onehot_i.reshape(G, S * top_k, E)
    pos = jnp.cumsum(flat, axis=1) - 1
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, S, top_k)   # (G, S, k)
    keep = pos < C
    gate_kept = gate_vals * keep

    # dispatch mask folded over k: (G, S, E, C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                            dtype=x.dtype)[..., :C]           # (G, S, k, C)
    disp = jnp.einsum("gske,gskc->gsec",
                      jax.nn.one_hot(idx, E, dtype=x.dtype), pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec",
                      jax.nn.one_hot(idx, E, dtype=x.dtype), pos_oh,
                      gate_kept.astype(x.dtype))

    xe = jnp.einsum("gsec,gsd->gecd", disp, xf)               # (G, E, C, d)
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("gecd,edf->gecf", xe, p["wi_up"],
                   preferred_element_type=jnp.float32)
    hh = (jax.nn.silu(h) * u).astype(x.dtype)
    ye = jnp.einsum("gecf,efd->gecd", hh, p["wo"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("gsec,gecd->gsd", comb, ye)
    return y.reshape(B, T, d), aux


def moe_decode(p, x_t, *, top_k=2):
    """Single-token-per-sequence MoE. x_t: (B, d)."""
    B, d = x_t.shape
    E = p["router"].shape[1]
    logits = jnp.dot(x_t.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, top_k)              # (B, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    oh = jax.nn.one_hot(idx, E, dtype=x_t.dtype)              # (B, k, E)
    w = jnp.einsum("bke,bk->be", oh, gate_vals.astype(x_t.dtype))
    h = jnp.einsum("bd,edf->bef", x_t, p["wi_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("bd,edf->bef", x_t, p["wi_up"],
                   preferred_element_type=jnp.float32)
    hh = (jax.nn.silu(h) * u).astype(x_t.dtype)
    ye = jnp.einsum("bef,efd->bed", hh, p["wo"],
                    preferred_element_type=jnp.float32).astype(x_t.dtype)
    return jnp.einsum("bed,be->bd", ye, w)
