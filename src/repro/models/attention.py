"""GQA / sliding-window attention with blockwise (flash-style) compute.

Three entry points per layer:
  * ``attn_train``   — full-sequence causal (optionally windowed) attention,
                       blockwise online-softmax scan over KV blocks: never
                       materializes the (T, T) score matrix (required for the
                       32k prefill and 4k train shapes at production batch).
  * ``attn_prefill`` — attn_train + returns the populated KV cache.
  * ``attn_decode``  — one new token against the cache. Pure-JAX einsum path
                       (GSPMD-shardable over batch / heads / cache length) or
                       the Pallas flash-decode kernel (`use_pallas`).

KV cache layout: (B, Hkv, Tmax, hd) + scalar lengths (B,).  For SWA archs the
cache is a rolling buffer of ``window`` positions (O(1) memory at 500k ctx).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers


class KVCache(NamedTuple):
    k: jax.Array          # (B, Hkv, Tmax, hd)
    v: jax.Array          # (B, Hkv, Tmax, hd)
    length: jax.Array     # (B,) int32 — tokens seen so far (may exceed Tmax
                          # for rolling SWA caches)


def init_attention(key, d_model, n_heads, n_kv_heads, head_dim,
                   dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d_model, n_heads, head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, n_kv_heads, head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, n_kv_heads, head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (n_heads, head_dim, d_model))
               * ((n_heads * head_dim) ** -0.5)).astype(dtype),
    }


def _qkv(p, x, positions, rope_theta):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"]).astype(x.dtype)
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"]).astype(x.dtype)
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"]).astype(x.dtype)
    q = layers.apply_rope(q, positions, rope_theta)
    k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


def blockwise_attention(q, k, v, *, window=None, block_kv=512):
    """Causal (optionally sliding-window) attention, scanned over KV blocks.

    q: (B, T, Hq, hd); k, v: (B, T, Hkv, hd).  Returns (B, T, Hq, hd).
    Memory per scan step: O(T * block_kv) scores instead of O(T^2).
    """
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    bkv = min(block_kv, T)
    n_blocks = T // bkv
    assert T % bkv == 0

    qg = q.reshape(B, T, Hkv, G, hd)
    kb = k.reshape(B, n_blocks, bkv, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n_blocks, bkv, Hkv, hd).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.arange(T)

    # remat the per-block step: without this, the backward pass stacks every
    # block's (B, T, H, bkv) score tensor as a saved residual — measured at
    # 38 GB/layer/device on the train_4k cells (see EXPERIMENTS.md §Perf i1).
    @jax.checkpoint
    def step(carry, inp):
        m, l, acc = carry                    # (B,T,Hkv,G) / same / (...,hd)
        kv_idx, k_blk, v_blk = inp           # k_blk: (B, bkv, Hkv, hd)
        # bf16 inputs with fp32 accumulation — no materialized fp32 k/v
        s = scale * jnp.einsum("bthgd,bshd->bthgs", qg, k_blk,
                               preferred_element_type=jnp.float32)
        kv_pos = kv_idx * bkv + jnp.arange(bkv)
        mask = q_pos[:, None] >= kv_pos[None, :]            # causal
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = corr * l + jnp.sum(p, axis=-1)
        acc_new = corr[..., None] * acc + jnp.einsum(
            "bthgs,bshd->bthgd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, T, Hkv, G), -1e30, jnp.float32)
    l0 = jnp.zeros((B, T, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, T, Hkv, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_blocks), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, T, Hq, hd).astype(q.dtype)


def _apply_head_mask(o, head_mask):
    """Zero the TP-padding heads (see ArchConfig.head_mask) — keeps padded
    attention mathematically identical to the unpadded model."""
    if head_mask is None:
        return o
    shape = (1,) * (o.ndim - 2) + (o.shape[-2], 1)
    return o * head_mask.reshape(shape).astype(o.dtype)


def attn_train(p, x, *, rope_theta=10000.0, window=None, block_kv=512,
               use_flash_kernel=False, head_mask=None):
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _qkv(p, x, positions, rope_theta)
    if use_flash_kernel:
        # Pallas fused path: scores stay in VMEM, HBM traffic O(B·T·H·d)
        from repro.kernels.flash_attn import flash_attention
        o = flash_attention(q, k, v, min(512, T), min(512, T), window,
                            jax.default_backend() != "tpu")
    else:
        o = blockwise_attention(q, k, v, window=window, block_kv=block_kv)
    o = _apply_head_mask(o, head_mask)
    return jnp.einsum("bthk,hkd->btd", o, p["wo"]).astype(x.dtype)


def attn_prefill(p, x, cache: KVCache, *, rope_theta=10000.0, window=None,
                 block_kv=512, head_mask=None):
    """Run full attention over the prompt and populate the cache.

    Assumes all sequences share length T (ragged prompts are left-padded by
    the serving engine).  Rolling SWA caches keep the last `size` tokens.
    """
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))
    q, k, v = _qkv(p, x, positions, rope_theta)
    o = blockwise_attention(q, k, v, window=window, block_kv=block_kv)
    size = cache.k.shape[2]
    kh = k.transpose(0, 2, 1, 3)          # (B, Hkv, T, hd)
    vh = v.transpose(0, 2, 1, 3)
    if T >= size:
        # keep the last `size` tokens, arranged so token p sits at slot
        # p mod size (required by the rolling insert in _cache_insert).
        new_k = jnp.roll(kh[:, :, -size:, :], T % size, axis=2)
        new_v = jnp.roll(vh[:, :, -size:, :], T % size, axis=2)
    else:
        new_k = jax.lax.dynamic_update_slice(
            cache.k, kh.astype(cache.k.dtype), (0, 0, 0, 0))
        new_v = jax.lax.dynamic_update_slice(
            cache.v, vh.astype(cache.v.dtype), (0, 0, 0, 0))
    new_cache = KVCache(new_k.astype(cache.k.dtype),
                        new_v.astype(cache.v.dtype),
                        cache.length + T)
    o = _apply_head_mask(o, head_mask)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"]).astype(x.dtype)
    return out, new_cache


def attn_prefill_chunk(p, x, cache: KVCache, *, rope_theta=10000.0,
                       window=None, head_mask=None, valid_len=None):
    """Process one prompt chunk *continuing from* the cache.

    Unlike ``attn_prefill`` (which assumes a fresh cache and positions
    starting at 0), this attends the chunk's queries against the cached KV
    *and* the in-chunk causal prefix, with RoPE positions offset by
    ``cache.length`` — the building block of the serving engine's chunked
    prefill.  Exactly equivalent to decoding the chunk token by token:
    a pre-chunk cache slot is visible to query at position ``pos`` iff it
    is occupied and its token is among the ``size`` most recent at ``pos``
    (the rolling buffer holds exactly those, so this matches what serial
    `attn_decode_xla` calls would see).

    ``valid_len`` (optional scalar or per-row (B,) int32) marks a ragged
    chunk padded to C: only the first valid_len tokens of each row are
    real.  Padded positions are
    **not** inserted into the rolling buffer (a wrapped-slot write would
    overwrite still-visible valid tokens) and ``length`` advances by
    ``valid_len`` only; their k/v never reach a valid query's scores
    (in-chunk visibility is causal, and every padded position sits after
    every valid one).  Output rows at padded positions are garbage —
    callers ignore them.

    x: (B, C, d_model) with C <= cache size (the rolling scatter writes
    each chunk token to a distinct slot).  Returns (out (B, C, d), cache).
    """
    B, C, _ = x.shape
    size = cache.k.shape[2]
    if C > size:
        raise ValueError(f"prefill chunk of {C} tokens exceeds the rolling "
                         f"KV buffer ({size}); lower the chunk size")
    pos = cache.length[:, None] + jnp.arange(C)[None, :]       # (B, C)
    q, k, v = _qkv(p, x, pos, rope_theta)
    Hq, hd = q.shape[2], q.shape[3]
    Hkv = cache.k.shape[1]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, C, Hkv, G, hd).transpose(0, 2, 3, 1, 4)  # (B,Hkv,G,C,hd)

    # --- scores vs the pre-chunk cache -------------------------------
    # slot t holds absolute position p_t = the largest p < length with
    # p = t (mod size); it is visible to query i iff occupied and within
    # the `size` most recent positions at pos_i (serial-decode rule).
    t_idx = jnp.arange(size)
    L = cache.length[:, None]                                  # (B, 1)
    p_t = (L - 1) - jnp.mod(L - 1 - t_idx[None, :], size)      # (B, size)
    occupied = t_idx[None, :] < L
    vis = occupied[:, None, :] & (p_t[:, None, :]
                                  > pos[:, :, None] - size)    # (B, C, size)
    s_cache = scale * jnp.einsum("bhgcd,bhtd->bhgct", qg, cache.k,
                                 preferred_element_type=jnp.float32)
    s_cache = jnp.where(vis[:, None, None, :, :], s_cache, -1e30)

    # --- in-chunk causal scores --------------------------------------
    # with C <= size every in-chunk position is within the most-recent
    # window of every later query, so the mask is plain causal
    kc = k.transpose(0, 2, 1, 3)                               # (B,Hkv,C,hd)
    vc = v.transpose(0, 2, 1, 3)
    s_chunk = scale * jnp.einsum("bhgcd,bhjd->bhgcj", qg, kc,
                                 preferred_element_type=jnp.float32)
    causal = jnp.arange(C)[:, None] >= jnp.arange(C)[None, :]
    s_chunk = jnp.where(causal[None, None, None, :, :], s_chunk, -1e30)

    # --- two-part online-softmax combine ------------------------------
    # The cache and chunk score blocks are softmaxed separately and merged
    # flash-style instead of concatenated: under a mesh the cache's context
    # dim is sharded on "model" (split-K decode) while the in-chunk scores
    # are replicated, and a concatenate along that mixed-sharded axis is
    # exactly the kind of resharding GSPMD handles worst (the -1e30 mask
    # values get mangled through the halo padding); the per-block
    # max/sum/weighted-sum reductions below partition cleanly.
    m_cache = jnp.max(s_cache, axis=-1)                        # (B,Hkv,G,C)
    e_cache = jnp.exp(s_cache - m_cache[..., None])
    l_cache = jnp.sum(e_cache, axis=-1)
    o_cache = jnp.einsum("bhgct,bhtd->bhgcd",
                         e_cache.astype(cache.v.dtype), cache.v,
                         preferred_element_type=jnp.float32)
    m_chunk = jnp.max(s_chunk, axis=-1)
    e_chunk = jnp.exp(s_chunk - m_chunk[..., None])
    l_chunk = jnp.sum(e_chunk, axis=-1)
    o_chunk = jnp.einsum("bhgcj,bhjd->bhgcd", e_chunk.astype(vc.dtype), vc,
                         preferred_element_type=jnp.float32)
    m = jnp.maximum(m_cache, m_chunk)
    # a fully-masked block has m_* = -1e30 => weight exp(-1e30 - m) == 0,
    # so its (garbage) unnormalized sums never contribute
    w_cache = jnp.exp(m_cache - m)
    w_chunk = jnp.exp(m_chunk - m)
    l = w_cache * l_cache + w_chunk * l_chunk
    o = (w_cache[..., None] * o_cache + w_chunk[..., None] * o_chunk) \
        / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, C, Hq, hd).astype(x.dtype)
    o = _apply_head_mask(o, head_mask)
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"]).astype(x.dtype)

    # --- rolling insert of the chunk (distinct slots since C <= size) -
    slots = jnp.mod(pos, size)                                 # (B, C)
    if valid_len is not None:
        # padded positions must not touch the buffer: in the rolling phase
        # their wrapped slot aliases a still-visible valid token.  Routing
        # them to the out-of-bounds slot `size` with mode="drop" makes the
        # scatter skip them entirely.  valid_len is a scalar or a per-row
        # (B,) vector (batched staging) — both reshape to (B or 1, 1).
        vl = jnp.reshape(jnp.asarray(valid_len, jnp.int32), (-1, 1))
        slots = jnp.where(jnp.arange(C)[None, :] < vl, slots, size)
    new_k = jax.vmap(lambda ck, kk, sl: ck.at[:, sl, :].set(
        kk.astype(ck.dtype), mode="drop"))(cache.k, kc, slots)
    new_v = jax.vmap(lambda cv, vv, sl: cv.at[:, sl, :].set(
        vv.astype(cv.dtype), mode="drop"))(cache.v, vc, slots)
    adv = C if valid_len is None else valid_len
    return out, KVCache(new_k, new_v, cache.length + adv)


def _cache_insert(cache: KVCache, k_t, v_t):
    """Insert one token at the rolling position. k_t: (B, Hkv, hd)."""
    size = cache.k.shape[2]
    slot = jnp.mod(cache.length, size)    # (B,) rolling slot (no-op when
                                          # size == max_len since length < size)
    b_idx = jnp.arange(cache.k.shape[0])
    new_k = cache.k.at[b_idx, :, slot, :].set(k_t.astype(cache.k.dtype))
    new_v = cache.v.at[b_idx, :, slot, :].set(v_t.astype(cache.v.dtype))
    return KVCache(new_k, new_v, cache.length + 1)


def attn_decode_xla(p, x_t, cache: KVCache, *, rope_theta=10000.0,
                    window=None, head_mask=None):
    """One-token decode, pure-JAX (GSPMD-shardable einsum over the cache).

    x_t: (B, d_model). Returns (out (B, d_model), new_cache).
    """
    B, d_model = x_t.shape
    pos = cache.length                    # (B,)
    q = jnp.einsum("bd,dhk->bhk", x_t, p["wq"]).astype(x_t.dtype)
    k = jnp.einsum("bd,dhk->bhk", x_t, p["wk"]).astype(x_t.dtype)
    v = jnp.einsum("bd,dhk->bhk", x_t, p["wv"]).astype(x_t.dtype)
    q = layers.apply_rope(q[:, None], pos[:, None], rope_theta)[:, 0]
    k = layers.apply_rope(k[:, None], pos[:, None], rope_theta)[:, 0]
    cache = _cache_insert(cache, k, v)

    size = cache.k.shape[2]
    Hq = q.shape[1]
    Hkv = cache.k.shape[1]
    G = Hq // Hkv
    hd = q.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    # mixed-precision einsums with fp32 accumulation: upcasting the cache
    # (`.astype(f32)`) materializes a full fp32 copy of the KV cache every
    # token — measured at ~50% of the decode memory term (§Perf i7)
    qg = q.reshape(B, Hkv, G, hd)
    s = scale * jnp.einsum("bhgd,bhtd->bhgt", qg, cache.k,
                           preferred_element_type=jnp.float32)
    # valid positions: slot t holds a token iff t < length (linear phase) or
    # always (rolling phase, length > size).  Window masking is implicit in
    # the rolling buffer size.
    t_idx = jnp.arange(size)
    valid = t_idx[None, :] < cache.length[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    p_att = jnp.exp(s - pmax)
    p_att = p_att / jnp.maximum(jnp.sum(p_att, -1, keepdims=True), 1e-30)
    o = jnp.einsum("bhgt,bhtd->bhgd", p_att.astype(cache.v.dtype), cache.v,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, Hq, hd).astype(x_t.dtype)
    o = _apply_head_mask(o, head_mask)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"]).astype(x_t.dtype)
    return out, cache


def attn_decode_pallas(p, x_t, cache: KVCache, *, rope_theta=10000.0,
                       window=None, block_t=256):
    """One-token decode through the Pallas flash-decode kernel."""
    from repro.kernels import ops
    pos = cache.length
    q = jnp.einsum("bd,dhk->bhk", x_t, p["wq"]).astype(x_t.dtype)
    k = jnp.einsum("bd,dhk->bhk", x_t, p["wk"]).astype(x_t.dtype)
    v = jnp.einsum("bd,dhk->bhk", x_t, p["wv"]).astype(x_t.dtype)
    q = layers.apply_rope(q[:, None], pos[:, None], rope_theta)[:, 0]
    k = layers.apply_rope(k[:, None], pos[:, None], rope_theta)[:, 0]
    cache = _cache_insert(cache, k, v)
    # raw token count: the kernel owns the occupancy clamp to the buffer
    o = ops.attn_decode(q, cache.k, cache.v, cache.length,
                        block_t=min(block_t, cache.k.shape[2]))
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"]).astype(x_t.dtype)
    return out, cache
