"""RG-LRU recurrent block (RecurrentGemma) — diagonal vector-state mixer.

The paper's persistence insight applies (the state is O(1) and must round-trip
HBM every token on GPU) but the *matrix-state MXU datapath does not*: the
RG-LRU state is a width-d vector with elementwise recurrence

    a_t = exp(-c * softplus(Lambda) * sigma(W_a x_t))        (gate)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (sigma(W_x x_t) * x_t)

so decode is a pure VPU workload; fusion (XLA already fuses the elementwise
chain into one kernel) is the TPU-idiomatic equivalent — see DESIGN.md
§Arch-applicability.  Train/prefill uses an associative scan over T.

Block layout follows RecurrentGemma: linear in -> causal conv(4) -> RG-LRU
-> gated (GeGLU-style) linear out.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers

# causal-conv width (RecurrentGemma block); the mixer registry's cache_spec
# must describe carries of exactly this width
CONV_WIDTH = 4

_C = 8.0  # RecurrentGemma's fixed gate sharpness constant


class RGLRUState(NamedTuple):
    h: jax.Array          # (B, width) fp32
    conv: jax.Array       # (B, conv_width-1, width)


def init_rglru(key, d_model, width, conv_width=CONV_WIDTH,
               dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    s = d_model ** -0.5
    sw = width ** -0.5
    return {
        "in_x": (jax.random.normal(ks[0], (d_model, width)) * s).astype(dtype),
        "in_y": (jax.random.normal(ks[1], (d_model, width)) * s).astype(dtype),
        "conv": layers.init_conv1d(ks[2], width, conv_width, dtype),
        "w_a": (jax.random.normal(ks[3], (width, width)) * sw).astype(dtype),
        "w_x": (jax.random.normal(ks[4], (width, width)) * sw).astype(dtype),
        "Lambda": jnp.full((width,), -4.0, jnp.float32),  # softplus^-1 region
        "out": (jax.random.normal(ks[5], (width, d_model)) * sw).astype(dtype),
    }


def _gates(p, x):
    """x: (..., width) -> (log_a, gated_input) in fp32."""
    r = jax.nn.sigmoid(layers.dot(x, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(layers.dot(x, p["w_x"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["Lambda"]) * r          # <= 0
    gated = i * x.astype(jnp.float32)
    return log_a, gated


def _scan_rglru(log_a, gated, h0):
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (T)."""
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = a_c * h0[:, None, :] + b_c
    return h


def rglru_train(p, x):
    B, T, _ = x.shape
    xb = layers.dot(x, p["in_x"])
    yb = jax.nn.gelu(layers.dot(x, p["in_y"]).astype(jnp.float32))
    xb = layers.conv1d_fwd(p["conv"], xb)
    log_a, gated = _gates(p, xb)
    h = _scan_rglru(log_a, gated, jnp.zeros((B, xb.shape[-1]), jnp.float32))
    out = (h * yb).astype(x.dtype)
    return layers.dot(out, p["out"])


def rglru_prefill(p, x, state: RGLRUState, valid_len=None):
    """``valid_len`` (optional scalar or per-row (B,) int32): positions
    >= valid_len are padding — their gates are forced to the identity
    (log_a = 0, input 0) so the carried h and the conv carry are exactly
    those after the valid prefix (padded output rows are garbage; callers
    ignore them).  A (B,) vector gathers each row's conv carry at its own
    boundary (the batched staging path); a scalar keeps the
    ``dynamic_slice`` path bitwise-unchanged."""
    B, T, _ = x.shape
    xb = layers.dot(x, p["in_x"])
    yb = jax.nn.gelu(layers.dot(x, p["in_y"]).astype(jnp.float32))
    conv_w = p["conv"]["w"].shape[0]
    full = jnp.concatenate([state.conv.astype(xb.dtype), xb], axis=1)
    if valid_len is None:
        new_conv = full[:, -(conv_w - 1):, :]
    else:
        vl = jnp.asarray(valid_len, jnp.int32)
        if vl.ndim == 0:
            new_conv = jax.lax.dynamic_slice_in_dim(full, vl,
                                                    conv_w - 1, axis=1)
        else:
            idx = vl[:, None] + jnp.arange(conv_w - 1)[None, :]
            new_conv = jnp.take_along_axis(full, idx[:, :, None], axis=1)
    xb = layers.conv1d_fwd(p["conv"], full)[:, -T:, :]
    log_a, gated = _gates(p, xb)
    if valid_len is not None:
        vl2 = jnp.reshape(jnp.asarray(valid_len, jnp.int32), (-1, 1))
        vm = (jnp.arange(T)[None, :] < vl2)[:, :, None]
        log_a = jnp.where(vm, log_a, jnp.zeros_like(log_a))   # a = 1
        gated = jnp.where(vm, gated, jnp.zeros_like(gated))   # b = 0
    h = _scan_rglru(log_a, gated, state.h)
    out = (h * yb).astype(x.dtype)
    return layers.dot(out, p["out"]), RGLRUState(
        h=h[:, -1, :], conv=new_conv.astype(state.conv.dtype))


def rglru_decode(p, x_t, state: RGLRUState):
    """One-token decode: a handful of fused elementwise VPU ops."""
    xb = layers.dot(x_t, p["in_x"])
    yb = jax.nn.gelu(layers.dot(x_t, p["in_y"]).astype(jnp.float32))
    xb, new_conv = layers.conv1d_decode(p["conv"], xb, state.conv)
    log_a, gated = _gates(p, xb)
    a = jnp.exp(log_a)
    h = a * state.h + jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * gated
    out = (h * yb).astype(x_t.dtype)
    return layers.dot(out, p["out"]), RGLRUState(h=h, conv=new_conv)
