"""Unified hybrid causal LM driving all assigned architectures.

A model is a cycled ``pattern`` of mixer kinds (any kind registered in
``repro.models.mixers`` — attn / swa / gdn / ssm / rglru / gdn_naive / ...)
plus a per-layer FFN (dense / moe / moe+dense / none).  Layers are grouped
into (pattern, repeats) groups and executed with ``lax.scan`` over stacked
parameters — compile time stays O(pattern) instead of O(n_layers) for the
60-layer archs, and remat wraps each scanned block.

Mixer dispatch is a registry lookup: this module never names a mixer kind.
Adding a kind is one module in ``repro.models.mixers`` implementing the
``SequenceMixer`` protocol; caches are materialized from each mixer's
declarative ``cache_spec`` (see ``cache_specs`` below), which is the same
source of truth the serving engine and the intensity model consume.

Entry points:
  init_lm(key, cfg)                         -> params
  forward_hidden(params, cfg, tokens|embeds)-> (B, T, d) final hidden
  loss_fn(params, cfg, batch)               -> scalar loss, metrics  (chunked CE)
  cache_specs(cfg, batch, max_len)          -> CacheSpec (declarative, stacked)
  init_caches(cfg, batch, max_len)          -> decode caches (per group, stacked)
  prefill(params, cfg, tokens|embeds, caches)-> (last-token logits, caches)
  prefill_chunk(params, cfg, caches, ...)   -> one prompt chunk, resumed from
                                               the caches (no logits)
  prefill_chunk_scan(params, cfg, caches, ..)-> n equal chunks in one scan
  prefill_sample(params, cfg, caches, sampler, sample_fn, ...)
                                            -> final chunk + fused first-token
                                               draw (on-device admit)
  decode_step(params, cfg, token, caches)   -> (logits, caches)
  decode_steps(params, cfg, tokens, caches, k, sampler, sample_fn)
                                            -> k fused decode+sample steps
                                               (one host sync per k tokens)

VLM / audio archs: the modality frontend is a stub per the assignment —
``embeds`` (precomputed patch/frame embeddings, (B, T, d_model)) are fed
directly in place of token embeddings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import layers, moe
from repro.models.mixers import CacheSpec, get_mixer


def _constrain(x, dp_axes):
    """Pin the batch dim of activations to the DP axes (GSPMD propagation
    otherwise drops batch sharding through gathers/microbatch reshapes)."""
    if dp_axes is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, P(dp_axes, *([None] * (x.ndim - 1))))


# ---------------------------------------------------------------- grouping

def build_groups(cfg: ArchConfig) -> List[Tuple[Tuple[str, ...], int]]:
    """[(pattern kinds, repeats)] covering cfg.n_layers."""
    L, P = cfg.n_layers, len(cfg.pattern)
    groups = []
    if L // P:
        groups.append((cfg.pattern, L // P))
    if L % P:
        groups.append((tuple(cfg.pattern[: L % P]), 1))
    return groups


# ---------------------------------------------------------------- init

def _init_layer(key, kind: str, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": layers.init_rmsnorm(cfg.d_model),
         "mixer": get_mixer(kind).init_params(ks[0], cfg, dtype)}
    if cfg.ffn != "none":
        p["norm2"] = layers.init_rmsnorm(cfg.d_model)
        if cfg.ffn in ("dense",):
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
        if cfg.ffn in ("moe", "moe+dense"):
            p["moe"] = moe.init_moe(ks[2], cfg.d_model, cfg.d_ff,
                                    cfg.moe_experts, dtype)
        if cfg.ffn == "moe+dense":
            p["mlp"] = layers.init_mlp(ks[1], cfg.d_model,
                                       cfg.d_ff_dense or cfg.d_ff, dtype)
    return p


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ArchConfig):
    dtype = jnp.dtype(cfg.act_dtype)
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": layers.init_embedding(k_embed, cfg.vocab, cfg.d_model,
                                       dtype),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": (jax.random.normal(k_head, (cfg.d_model, cfg.vocab))
                  * cfg.d_model ** -0.5).astype(dtype)}
    groups = build_groups(cfg)
    layer_keys = iter(jax.random.split(k_layers, cfg.n_layers))
    gparams = []
    for kinds, reps in groups:
        per_pos: List[List[Any]] = [[] for _ in kinds]
        for _ in range(reps):
            for i, kind in enumerate(kinds):
                per_pos[i].append(_init_layer(next(layer_keys), kind, cfg,
                                              dtype))
        gparams.append([_stack(ps) for ps in per_pos])
    params["groups"] = gparams
    return params


# ---------------------------------------------------------------- layer fwd

def _ffn_fwd(cfg: ArchConfig, lp, x, decode: bool):
    if cfg.ffn == "none":
        return x, 0.0
    h = layers.rmsnorm_fwd(lp["norm2"], x, cfg.norm_eps)
    aux = 0.0
    y = 0.0
    if "moe" in lp:
        if decode:
            y = y + moe.moe_decode(lp["moe"], h, top_k=cfg.moe_top_k)
        else:
            ym, aux = moe.moe_fwd(lp["moe"], h, top_k=cfg.moe_top_k,
                                  group_size=cfg.moe_group_size,
                                  capacity_factor=cfg.moe_capacity_factor)
            y = y + ym
    if "mlp" in lp:
        y = y + layers.mlp_fwd(lp["mlp"], h)
    return x + y, aux


def _layer_train(kind, cfg: ArchConfig, lp, x):
    h = layers.rmsnorm_fwd(lp["norm1"], x, cfg.norm_eps)
    x = x + get_mixer(kind).train(lp["mixer"], cfg, h)
    x, aux = _ffn_fwd(cfg, lp, x, decode=False)
    return x, aux


# ---------------------------------------------------------------- train fwd

def forward_hidden(params, cfg: ArchConfig, tokens=None, embeds=None,
                   dp_axes=None):
    """Returns (final hidden (B, T, d), total MoE aux loss)."""
    x = embeds if embeds is not None else layers.embed_fwd(params["embed"],
                                                           tokens)
    x = _constrain(x.astype(jnp.dtype(cfg.act_dtype)), dp_axes)
    aux_total = jnp.float32(0.0)
    groups = build_groups(cfg)
    for (kinds, reps), gp in zip(groups, params["groups"]):

        def block(x, lp_slice, kinds=kinds):
            aux = jnp.float32(0.0)
            for i, kind in enumerate(kinds):
                x, a = _layer_train(kind, cfg, lp_slice[i], x)
                x = _constrain(x, dp_axes)
                aux = aux + a
            return x, aux

        if cfg.remat:
            block = jax.checkpoint(block)

        x, auxs = jax.lax.scan(block, x, gp)
        aux_total = aux_total + jnp.sum(auxs)
    return x, aux_total


def _logits(params, cfg: ArchConfig, h):
    if cfg.tie_embeddings:
        return layers.logits_fwd(params["embed"], h)
    return jax.lax.dot_general(
        h, params["lm_head"]["w"], (((h.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def loss_fn(params, cfg: ArchConfig, batch, *, t_chunk=1024, z_loss=1e-4,
            aux_weight=0.01, dp_axes=None):
    """Chunked-over-T cross entropy (never materializes (B, T, V) fp32)."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    labels = batch["labels"]
    h, aux = forward_hidden(params, cfg, tokens, embeds, dp_axes=dp_axes)
    B, T, _ = h.shape
    tc = min(t_chunk, T)
    n = T // tc

    def chunk_loss(hc, lc):
        logits = _logits(params, cfg, hc)
        return layers.cross_entropy(logits, lc, z_loss=z_loss)

    if n <= 1:
        ce = chunk_loss(h, labels)
    else:
        hc = h[:, : n * tc].reshape(B, n, tc, -1).transpose(1, 0, 2, 3)
        lc = labels[:, : n * tc].reshape(B, n, tc).transpose(1, 0, 2)
        losses = jax.lax.map(jax.checkpoint(lambda args: chunk_loss(*args)),
                             (hc, lc))
        ce = jnp.mean(losses)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------- caches

def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> CacheSpec:
    """Declarative spec of the full decode-cache pytree, in the stacked
    per-group layout that ``prefill``/``decode_step`` scan over: leaves are
    (repeats, batch, ...).  The serving engine sizes its slot buffers and
    byte budgets from this; ``init_caches`` materializes it."""
    groups_spec = []
    for kinds, reps in build_groups(cfg):
        per_pos = []
        for kind in kinds:
            spec = get_mixer(kind).cache_spec(cfg, batch, max_len)
            per_pos.append(spec.stack(reps).tree)
        groups_spec.append(per_pos)
    return CacheSpec(groups_spec)


def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    """Stacked per-group caches matching the scanned param layout."""
    return cache_specs(cfg, batch, max_len).zeros()


def checkpoint_specs(cfg: ArchConfig, batch: int, max_len: int) -> CacheSpec:
    """Declarative spec of the speculative-decode rollback image, stacked
    like ``cache_specs``.  Built from each mixer's ``checkpoint_spec`` (the
    registry propagates the one-extra-state-copy-per-slot cost to the
    engine, the sharding planner and the intensity model without engine
    edits); for every built-in kind it equals ``cache_specs`` because
    decode mutates each cache leaf destructively."""
    groups_spec = []
    for kinds, reps in build_groups(cfg):
        per_pos = []
        for kind in kinds:
            spec = get_mixer(kind).checkpoint_spec(cfg, batch, max_len)
            per_pos.append(spec.stack(reps).tree)
        groups_spec.append(per_pos)
    return CacheSpec(groups_spec)


# ---------------------------------------------------------------- prefill / decode

def _run_cached(params, cfg: ArchConfig, x, caches, mode: str,
                dp_axes=None, valid_len=None):
    groups = build_groups(cfg)
    new_caches = []
    for (kinds, reps), gp, gc in zip(groups, params["groups"], caches):

        def block(x, sl, kinds=kinds):
            lp_slice, c_slice = sl
            new_c = []
            for i, kind in enumerate(kinds):
                lp = lp_slice[i]
                mixer = get_mixer(kind)
                h = layers.rmsnorm_fwd(lp["norm1"], x, cfg.norm_eps)
                if mode == "prefill":
                    mix, nc = mixer.prefill(lp["mixer"], cfg, h, c_slice[i])
                elif mode == "chunk":
                    mix, nc = mixer.prefill_chunk(lp["mixer"], cfg, h,
                                                  c_slice[i],
                                                  valid_len=valid_len)
                else:
                    mix, nc = mixer.decode(lp["mixer"], cfg, h, c_slice[i])
                x = x + mix
                x, _ = _ffn_fwd(cfg, lp, x, decode=(mode == "decode"))
                x = _constrain(x, dp_axes)
                new_c.append(nc)
            return x, new_c

        x, ncs = jax.lax.scan(block, x, (gp, gc))
        new_caches.append(ncs)
    return x, new_caches


def prefill(params, cfg: ArchConfig, caches, tokens=None, embeds=None,
            dp_axes=None):
    """Process the prompt; returns (last-token logits (B, V) fp32, caches)."""
    x = embeds if embeds is not None else layers.embed_fwd(params["embed"],
                                                           tokens)
    x = _constrain(x.astype(jnp.dtype(cfg.act_dtype)), dp_axes)
    x, caches = _run_cached(params, cfg, x, caches, "prefill",
                            dp_axes=dp_axes)
    x = layers.rmsnorm_fwd(params["final_norm"], x[:, -1], cfg.norm_eps)
    return _logits(params, cfg, x), caches


def prefill_chunk(params, cfg: ArchConfig, caches, tokens=None, embeds=None,
                  dp_axes=None, valid_len=None):
    """Process one prompt chunk *continuing from* ``caches``.

    Unlike ``prefill`` this never computes logits (interior chunks don't
    need them — the lm head on every chunk would be pure waste) and every
    mixer resumes from its cache state (attention continues RoPE/visibility
    at the cached position via ``prefill_chunk``).  Returns (final hidden
    (B, C, d), caches); feed the last chunk to ``prefill_sample`` for the
    logits + fused first-token draw.

    ``valid_len`` (optional scalar or per-row (B,) int32) marks a
    *ragged* chunk padded to its static size C: only the first valid_len
    tokens of each row are real.  Every
    mixer masks the padding so the returned caches are exactly those of
    the unpadded prefix — one fixed-size masked program replaces the
    whole family of tail-sized programs.  Hidden rows at padded positions
    are garbage; callers must only read rows < valid_len.
    """
    x = embeds if embeds is not None else layers.embed_fwd(params["embed"],
                                                           tokens)
    x = _constrain(x.astype(jnp.dtype(cfg.act_dtype)), dp_axes)
    return _run_cached(params, cfg, x, caches, "chunk", dp_axes=dp_axes,
                       valid_len=valid_len)


def prefill_chunk_scan(params, cfg: ArchConfig, caches, tokens=None,
                       embeds=None, dp_axes=None, valid_lens=None):
    """``lax.scan`` of ``prefill_chunk`` over equal-size prompt chunks.

    tokens: (B, n, C) int32 / embeds: (B, n, C, d) — n chunks of C tokens
    each, processed in order with the caches threaded through the scan, so
    one compiled program covers n chunks of prefill (the serving executor
    compiles one such program per scan length n).  Returns caches.

    ``valid_lens`` (optional (n,) or (n, B) int32): per-chunk valid-token
    counts for ragged prompts padded into the fixed (n, C) layout — a
    chunk with valid_lens[i] == 0 is a pure no-op on the caches, so one
    scan shape covers any number of trailing placeholder chunks.  The
    (n, B) form carries a *per-row* count per scan step (the batched
    multi-prompt staging path): the scan unstacks the leading axis, so
    each step's chunk sees a (B,) valid_len vector.
    """
    xs = tokens if tokens is not None else embeds
    xs = jnp.moveaxis(xs, 1, 0)                    # (n, B, C[, d])
    if valid_lens is not None:
        xs = (xs, jnp.asarray(valid_lens, jnp.int32))

    def body(caches, inp):
        chunk, vl = inp if valid_lens is not None else (inp, None)
        if tokens is not None:
            _, caches = prefill_chunk(params, cfg, caches, tokens=chunk,
                                      dp_axes=dp_axes, valid_len=vl)
        else:
            _, caches = prefill_chunk(params, cfg, caches, embeds=chunk,
                                      dp_axes=dp_axes, valid_len=vl)
        return caches, None

    caches, _ = jax.lax.scan(body, caches, xs)
    return caches


def prefill_sample(params, cfg: ArchConfig, caches, sampler, sample_fn,
                   tokens=None, embeds=None, dp_axes=None, valid_len=None):
    """Final prompt chunk with the fused admit head: one dispatch computes
    the chunk, the last-token logits and the first sampled token, and
    advances the sampler state (key split, budget decrement, EOS/budget
    done flag) — no host ``sample_np`` draw on the admit hot path.

    ``sampler``/``sample_fn`` as in ``decode_steps`` (the serving executor
    passes a 1-row ``repro.serving.sampling`` state and its ``sample``).
    ``valid_len`` marks a ragged final chunk: the admit logits come from
    the last *valid* position, not the last row of the padded chunk.  It
    may be a per-row (B,) vector (batched multi-prompt admit) — each row
    reads its own last valid position; a valid_len=0 placeholder row is
    clamped to position 0 (its token is garbage and the caller's admit
    mask discards it).  Returns (token (B,), sampler, caches).
    """
    x, caches = prefill_chunk(params, cfg, caches, tokens=tokens,
                              embeds=embeds, dp_axes=dp_axes,
                              valid_len=valid_len)
    if valid_len is None:
        h_last = x[:, -1]
    else:
        vl = jnp.asarray(valid_len, jnp.int32)
        if vl.ndim == 0:
            h_last = jax.lax.dynamic_slice_in_dim(x, vl - 1, 1,
                                                  axis=1)[:, 0]
        else:
            idx = jnp.maximum(vl - 1, 0)[:, None, None]        # (B, 1, 1)
            h_last = jnp.take_along_axis(x, idx, axis=1)[:, 0]
    h = layers.rmsnorm_fwd(params["final_norm"], h_last, cfg.norm_eps)
    tok, sampler = sample_fn(sampler, _logits(params, cfg, h))
    return tok.astype(jnp.int32), sampler, caches


def decode_step(params, cfg: ArchConfig, tokens_t, caches, dp_axes=None):
    """One decode step. tokens_t: (B,) int32. Returns (logits (B, V), caches)."""
    x = layers.embed_fwd(params["embed"], tokens_t)
    x = _constrain(x.astype(jnp.dtype(cfg.act_dtype)), dp_axes)
    x, caches = _run_cached(params, cfg, x, caches, "decode",
                            dp_axes=dp_axes)
    x = layers.rmsnorm_fwd(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), caches


def _greedy_sample(sampler, logits):
    """Default on-device sampler: argmax, state untouched (never done)."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), sampler


def decode_steps(params, cfg: ArchConfig, tokens, caches, k: int,
                 sampler=None, sample_fn=None, dp_axes=None):
    """``k`` fused decode+sample steps in one ``lax.scan``.

    This is the device-resident decode hot loop: the recurrent state,
    the sampled tokens and the finished flags all stay on device for
    ``k`` consecutive tokens, so a caller (the serving engine) syncs
    with the host once per ``k`` tokens instead of once per token —
    the serving-layer analogue of the paper's keep-state-resident
    argument, and the building block for speculative / multi-device
    decode.

    ``sampler`` is any pytree carrying a ``"done"`` (B,) bool leaf;
    ``sample_fn(sampler, logits) -> ((B,) int32 tokens, sampler)``
    draws the next token batch and advances the done flags (see
    ``repro.serving.sampling.sample``).  Omitting both gives greedy
    argmax with no termination.  Slots whose ``done`` flag is set
    before a step are masked: they re-feed their last token (their
    slot cache advances with garbage, which is fine — admit rewrites
    the whole slot) and that step is marked invalid for them.

    Returns ``(toks (k, B) int32, valid (k, B) bool, tokens (B,),
    caches, sampler)`` — ``toks[j]`` is the token batch from step j,
    ``valid[j]`` whether each slot was still live going into step j.
    """
    if sample_fn is None:
        sample_fn = _greedy_sample
    if sampler is None:
        sampler = {"done": jnp.zeros(tokens.shape, bool)}

    def step(carry, _):
        toks, cs, st = carry
        live = ~st["done"]
        logits, cs = decode_step(params, cfg, toks, cs, dp_axes=dp_axes)
        nxt, st = sample_fn(st, logits)
        nxt = jnp.where(live, nxt, toks)
        return (nxt, cs, st), (nxt, live)

    (tokens, caches, sampler), (toks, valid) = jax.lax.scan(
        step, (tokens, caches, sampler), None, length=k)
    return toks, valid, tokens, caches, sampler


def verify_steps(params, cfg: ArchConfig, draft_params, draft_cfg,
                 tokens, drafts, caches, draft_caches, sampler, sample_fn,
                 dp_axes=None):
    """Speculative verify: score K drafted tokens per slot against the
    target model and commit per-slot state only for emitted positions.

    One teacher-forced ``lax.scan`` over K+1 positions feeds the slot's
    last emitted token followed by its K draft tokens through
    ``decode_step`` — the *same* arithmetic as non-speculative decode, so
    every emitted token (and the state it leaves behind) is bitwise what
    the plain tick would have produced.  (A chunkwise-prefill verify
    would be one parallel program, but GDN's chunkwise UT transform is a
    numerically different factorization from the fused decode step, so
    it could never be bitwise-lossless; ``core.gdn.prefill_sequential``
    is the existing precedent for scanning the decode step instead.)

    Position j samples the target token t_j with the slot's own key
    stream (``sample_fn(sampler, logits, active)`` — a masked sampler
    like ``sampling.sample_where`` that only advances rows where
    ``active``); the slot keeps accepting while t_j equals the draft
    token it is about to feed next.  Because draft and target share the
    (seed, rid)-folded key at every position, coupled rejection sampling
    collapses to that token-equality check for greedy *and* stochastic
    slots.  A slot emits m ∈ {1..K+1} tokens (its correction or bonus
    token last) and zero if it entered the tick done.

    Rollback is the conditional commit: the scan carries a run-ahead
    cache tree *and* a committed tree, selecting run-ahead into the
    commit only at active positions, so a slot whose entire draft is
    rejected ends the tick with bitwise-unchanged committed state — no
    replay pass.  ``draft_params``/``draft_caches`` run the same inputs
    through the draft model so its per-slot state tracks the emitted
    prefix (the committed draft tree is what the next draft pass starts
    from).

    tokens: (B,) last emitted per slot; drafts: (K, B) int32 (K may be
    0: a verify-only tick degenerates to one plain decode step).
    Returns ``(toks (K+1, B), valid (K+1, B), tokens (B,), caches,
    draft_caches, run, draft_run, sampler)`` where ``caches`` /
    ``draft_caches`` are the committed trees and ``run`` / ``draft_run``
    the run-ahead finals (the executor keeps them as the next tick's
    checkpoint scratch buffers).
    """
    k = drafts.shape[0]
    inp = jnp.concatenate([tokens[None], drafts.astype(jnp.int32)], axis=0)
    # token position j must match the input fed at j+1 to keep accepting;
    # the last position has no successor (its emission is the free bonus
    # token when all K drafts were accepted)
    nxt = jnp.concatenate([drafts.astype(jnp.int32),
                           jnp.full_like(tokens[None], -1)], axis=0)

    def commit_where(emit, new, old):
        def sel(n, o):
            m = emit.reshape((1, emit.shape[0]) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        return jax.tree.map(sel, new, old)

    def step(carry, xs):
        run, drun, com, dcom, st, acc, last = carry
        tin, d = xs
        active = acc & ~st["done"]
        logits, run = decode_step(params, cfg, tin, run, dp_axes=dp_axes)
        _, drun = decode_step(draft_params, draft_cfg, tin, drun,
                              dp_axes=dp_axes)
        tok, st = sample_fn(st, logits, active)
        tok = jnp.where(active, tok.astype(jnp.int32), last)
        com = commit_where(active, run, com)
        dcom = commit_where(active, drun, dcom)
        # stop at the first mismatch — and at EOS/budget exhaustion, even
        # when the draft guessed the EOS token (the slot is done; feeding
        # further drafts would emit past its end)
        acc = active & (tok == d) & ~st["done"]
        return (run, drun, com, dcom, st, acc, tok), (tok, active)

    init = (caches, draft_caches, caches, draft_caches, sampler,
            jnp.ones(tokens.shape, bool), tokens)
    (run, drun, com, dcom, sampler, _, last), (toks, valid) = jax.lax.scan(
        step, init, (inp, nxt))
    return toks, valid, last, com, dcom, run, drun, sampler
