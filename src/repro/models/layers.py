"""Shared model layers: RMSNorm, RoPE, SwiGLU MLP, embeddings, causal conv.

Functional style: ``init_*`` returns a param pytree (nested dicts of arrays),
``*_fwd`` applies it.  All matmuls run in the activation dtype (bf16 on TPU)
with fp32 accumulation via ``preferred_element_type``; norms and softmax in
fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dot(x, w):
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x.dtype)


# ------------------------------------------------------------------ RMSNorm

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm_fwd(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ------------------------------------------------------------------ RoPE

def rope_freqs(head_dim, theta=10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta=10000.0):
    """x: (..., T, H, hd) or (..., H, hd) with positions broadcastable."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, hd/2)
    cos = jnp.cos(ang)[..., None, :]                  # (..., T, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------ MLP

def init_mlp(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model ** -0.5
    s_ff = d_ff ** -0.5
    return {
        "wi_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wi_up": (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_ff).astype(dtype),
    }


def mlp_fwd(p, x):
    g = dot(x, p["wi_gate"])
    u = dot(x, p["wi_up"])
    return dot(jax.nn.silu(g) * u, p["wo"])


# ------------------------------------------------------------------ Embedding

def init_embedding(key, vocab, d_model, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d_model))
                      * (d_model ** -0.5)).astype(dtype)}


def embed_fwd(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def logits_fwd(p, x, table=None):
    """Project to vocab. table given => tied embeddings."""
    w = table if table is not None else p["table"]
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


# ------------------------------------------------------------------ causal conv1d
# (mamba2 / short-conv mixers; decode keeps a (width-1)-token cache)

def init_conv1d(key, channels, width, dtype=jnp.float32):
    w = jax.random.normal(key, (width, channels)) * (width ** -0.5)
    return {"w": w.astype(dtype), "b": jnp.zeros((channels,), dtype)}


def conv1d_fwd(p, x):
    """Causal depthwise conv over (B, T, C)."""
    width = p["w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["w"][i] for i in range(width))
    return out + p["b"]


def conv1d_decode(p, x_t, cache):
    """One-step conv. x_t: (B, C); cache: (B, width-1, C). Returns (y, cache)."""
    width = p["w"].shape[0]
    full = jnp.concatenate([cache, x_t[:, None, :]], axis=1)  # (B, width, C)
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                   p["w"].astype(jnp.float32)).astype(x_t.dtype) + p["b"]
    return y, full[:, 1:, :]


# ------------------------------------------------------------------ loss

def cross_entropy(logits, labels, z_loss=0.0):
    """logits: (..., V) fp32; labels: (...) int32. Mean over all positions."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss > 0.0:
        loss = loss + z_loss * lse ** 2
    return jnp.mean(loss)
