"""Gated DeltaNet mixer layer — the paper's primitive as a model layer.

Projects the residual stream to q/k (h_k heads) and v (h_v = R*h_k heads,
Grouped Value Attention), computes the per-head gates from token-dependent
inputs (paper Eqs. 5-6), L2-normalizes q/k (delta-rule stability), and runs:

  * train / prefill: chunkwise-parallel gated delta rule
    (pure-JAX `core.gdn.gdn_prefill` for the differentiable path,
     Pallas `kernels.ops.gdn_prefill` with VMEM-resident state for serving)
  * decode: the fused one-read-one-write persistent-state step
    (pure-JAX fused Alg. 2, or the Pallas `gdn_decode` kernel on TPU)

State cache: GDNState(S (B, Hv, d_k, d_v) fp32, conv carries none — the
paper's layer has no conv).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import gdn as gdn_core
from repro.models import layers


class GDNState(NamedTuple):
    S: jax.Array          # (B, Hv, d_k, d_v) fp32 — the persistent state


def init_gdn(key, d_model, n_k_heads, n_v_heads, head_dim,
             dtype=jnp.float32):
    ks = jax.random.split(key, 7)
    s = d_model ** -0.5
    hv, hk, hd = n_v_heads, n_k_heads, head_dim
    return {
        "wq": (jax.random.normal(ks[0], (d_model, hk, hd)) * s).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d_model, hk, hd)) * s).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d_model, hv, hd)) * s).astype(dtype),
        "wo": (jax.random.normal(ks[3], (hv, hd, d_model))
               * ((hv * hd) ** -0.5)).astype(dtype),
        "w_alpha": (jax.random.normal(ks[4], (d_model, hv)) * s).astype(dtype),
        "w_beta": (jax.random.normal(ks[5], (d_model, hv)) * s).astype(dtype),
        # per-head learned gate parameters (paper Eq. 5)
        "A_log": jnp.zeros((hv,), jnp.float32),
        "dt_bias": jnp.full((hv,), 0.5, jnp.float32),
    }


def _l2norm(x, eps=1e-6):
    n = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32)), -1,
                         keepdims=True) + eps)
    return (x.astype(jnp.float32) / n).astype(x.dtype)


def _proj(p, x):
    """x: (B, T, d) -> q,k (B,T,Hk,hd), v (B,T,Hv,hd), log_g/beta (B,T,Hv)."""
    q = _l2norm(jnp.einsum("btd,dhk->bthk", x, p["wq"]).astype(x.dtype))
    k = _l2norm(jnp.einsum("btd,dhk->bthk", x, p["wk"]).astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"]).astype(x.dtype)
    alpha = jnp.einsum("btd,dh->bth", x, p["w_alpha"]).astype(jnp.float32)
    b = jnp.einsum("btd,dh->bth", x, p["w_beta"]).astype(jnp.float32)
    log_g = gdn_core.log_gate(alpha, p["A_log"], p["dt_bias"])
    beta = jax.nn.sigmoid(b)
    return q, k, v, log_g, beta


def gdn_train(p, x, *, chunk=64):
    """Full-sequence gated delta rule (differentiable chunkwise path)."""
    B, T, _ = x.shape
    hv = p["wv"].shape[1]
    hd = p["wv"].shape[2]
    q, k, v, log_g, beta = _proj(p, x)
    S0 = jnp.zeros((B, hv, q.shape[-1], hd), jnp.float32)
    O, _ = gdn_core.gdn_prefill(q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), log_g, beta, S0,
                                chunk=chunk)
    O = O.astype(x.dtype)
    return jnp.einsum("bthk,hkd->btd", O, p["wo"]).astype(x.dtype)


def mask_ragged_inputs(valid_len, k, v, log_g, beta):
    """Zero the kernel inputs at padded positions (>= ``valid_len``).

    A padded token with k = v = beta = 0 and log_g = 0 (gate 1) is an exact
    no-op on the recurrent state and contributes nothing to any valid
    output row, so a fixed-size chunk with a ragged tail computes the same
    state/output as the unpadded sequence (outputs at padded rows are
    garbage — callers ignore them).  ``valid_len``: scalar int32, or a
    (B,) vector for per-row raggedness (the batched multi-prompt staging
    path) — a scalar broadcasts to every row bitwise-identically.
    """
    vl = jnp.reshape(jnp.asarray(valid_len, jnp.int32), (-1, 1))
    vm = jnp.arange(k.shape[1])[None, :] < vl          # (B or 1, T)
    k = jnp.where(vm[:, :, None, None], k, jnp.zeros_like(k))
    v = jnp.where(vm[:, :, None, None], v, jnp.zeros_like(v))
    log_g = jnp.where(vm[:, :, None], log_g, jnp.zeros_like(log_g))
    beta = jnp.where(vm[:, :, None], beta, jnp.zeros_like(beta))
    return k, v, log_g, beta


def gdn_prefill(p, x, state: GDNState, *, chunk=64, use_pallas=False,
                valid_len=None):
    """Prompt processing; returns (out, final state).

    ``valid_len`` (optional scalar or per-row (B,) int32): positions
    >= valid_len of ``x`` are padding — masked so the returned state
    equals the unpadded run (the Pallas kernel masks internally; the XLA
    path pre-masks k/v/gates).
    """
    q, k, v, log_g, beta = _proj(p, x)
    if use_pallas:
        from repro.kernels import ops
        O, S = ops.gdn_prefill(q, k, v, log_g, beta, state.S, chunk=chunk,
                               valid_len=valid_len)
    else:
        if valid_len is not None:
            k, v, log_g, beta = mask_ragged_inputs(valid_len, k, v,
                                                   log_g, beta)
        O, S = gdn_core.gdn_prefill(
            q.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), log_g, beta,
            state.S.astype(jnp.float32), chunk=chunk)
        S = S.astype(state.S.dtype)
    O = O.astype(x.dtype)
    out = jnp.einsum("bthk,hkd->btd", O, p["wo"]).astype(x.dtype)
    return out, GDNState(S=S)


def gdn_decode(p, x_t, state: GDNState, *, use_pallas=False, head_block=8,
               fused=True):
    """One-token decode step: paper Alg. 2 (fused, default) or the Alg. 1
    three-pass reference (`fused=False`, the `gdn_naive` registry kind —
    XLA path only). x_t: (B, d_model)."""
    x = x_t[:, None, :]
    q, k, v, log_g, beta = _proj(p, x)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    g = jnp.exp(log_g[:, 0])
    beta = beta[:, 0]
    if use_pallas and fused:
        from repro.kernels import ops
        o, S = ops.gdn_decode(q, k, v, state.S, g, beta,
                              head_block=head_block)
    else:
        o, S = gdn_core.gdn_decode(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32),
                                   state.S.astype(jnp.float32), g, beta,
                                   fused=fused)
        o = o.astype(x_t.dtype)
        S = S.astype(state.S.dtype)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"]).astype(x_t.dtype)
    return out, GDNState(S=S)
