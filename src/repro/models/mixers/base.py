"""SequenceMixer protocol + declarative persistent-state cache specs.

The paper's architectural claim is that every subquadratic mixer is the same
workload: a fixed-size persistent state touched once per token.  This module
is that claim as an interface.  A mixer kind is one class implementing

  init_params(key, cfg, dtype)     -> parameter pytree
  train(params, cfg, x)            -> (B, T, d) mixed output
  prefill(params, cfg, x, cache)   -> ((B, T, d) out, new cache)
  decode(params, cfg, x_t, cache)  -> ((B, d) out, new cache)
  cache_spec(cfg, batch, max_len)  -> CacheSpec (declarative state layout)
  init_cache(cfg, batch, max_len)  -> cache pytree (default: spec zeros)

plus declarative class attributes consumed by the serving engine, the
sharding planner and the intensity model:

  kind          registry name (the string used in ArchConfig.pattern)
  is_attention  softmax-attention family (KV cache instead of fixed state)
  quadratic     O(T) decode state (unwindowed full attention)
  state_passes  HBM round-trips over the persistent state per decoded token
                on a naive (non-persistent) backend: reads + writes

``CacheSpec`` mirrors the runtime cache pytree with ``ArraySpec`` leaves, so
slot buffers, byte budgets and roofline terms are all derived from one
declaration instead of per-kind formulas scattered across the codebase.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    """Shape/dtype/role of one cache leaf.

    role: "state"  — fixed-size recurrent state (S matrices, conv carries,
                     RG-LRU vectors): the paper's persistent state;
          "window" — context-sized buffers read once per token (KV caches,
                     rolling SWA windows);
          "meta"   — bookkeeping scalars (sequence lengths), not counted in
                     byte budgets.
    """
    shape: Tuple[int, ...]
    dtype: Any
    role: str = "state"

    @property
    def nbytes(self) -> int:
        return int(math.prod(self.shape)) * np.dtype(self.dtype).itemsize

    def stack(self, reps: int) -> "ArraySpec":
        return ArraySpec((reps,) + tuple(self.shape), self.dtype, self.role)


def _spec_leaves(tree):
    return [l for l in jax.tree.leaves(tree) if isinstance(l, ArraySpec)]


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """A pytree of ArraySpec leaves mirroring the runtime cache structure."""
    tree: Any

    def leaves(self):
        return _spec_leaves(self.tree)

    def zeros(self):
        """Materialize the cache buffers this spec describes (all-zero init
        is part of the contract: slot admit may skip clearing freed slots)."""
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, jnp.dtype(s.dtype)), self.tree)

    def shape_dtype(self):
        """The spec as a jax.ShapeDtypeStruct pytree (for jit.lower etc.)."""
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
            self.tree)

    def stack(self, reps: int) -> "CacheSpec":
        """Add a leading layer-stack dim to every leaf (scanned layouts)."""
        return CacheSpec(jax.tree.map(lambda s: s.stack(reps), self.tree))

    def _role_bytes(self, role: str) -> int:
        return sum(l.nbytes for l in self.leaves() if l.role == role)

    @property
    def state_bytes(self) -> int:
        """Fixed-size persistent recurrent state (paper Eq. 8 budget)."""
        return self._role_bytes("state")

    @property
    def window_bytes(self) -> int:
        """Context-sized buffers (KV / rolling windows)."""
        return self._role_bytes("window")

    @property
    def nbytes(self) -> int:
        """Total buffer bytes (including meta) — the HBM footprint."""
        return sum(l.nbytes for l in self.leaves())


class SequenceMixer:
    """Base class for registered mixer kinds.  Subclasses override the
    classmethods; every method takes the full ArchConfig so adding a mixer
    never requires threading new per-kind kwargs through the model."""

    kind: str = ""
    is_attention: bool = False
    quadratic: bool = False
    state_passes: int = 2          # naive backend: 1 read + 1 write
    # declarative capability: True iff prefill_chunk implements the
    # per-token validity mask (ragged fixed-size chunks).  The serving
    # executor's masked planner requires it from every kind in the
    # pattern and falls back to pow2 tail plans otherwise — a kind
    # registered without masking still serves, it just pays the larger
    # compile cache.
    supports_ragged_prefill: bool = False
    # True iff prefill_chunk additionally accepts a per-row (B,) valid_len
    # vector (each batch row ragged at its own boundary).  The batched
    # multi-prompt staging path (one fused prefill program over all staged
    # prompts per tick) requires it from every kind in the pattern; the
    # executor falls back to per-prompt dispatch otherwise.  Implies
    # supports_ragged_prefill.
    supports_batched_ragged_prefill: bool = False

    @classmethod
    def init_params(cls, key, cfg, dtype):
        raise NotImplementedError(cls.kind)

    @classmethod
    def train(cls, params, cfg, x):
        raise NotImplementedError(cls.kind)

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        raise NotImplementedError(cls.kind)

    @classmethod
    def prefill_chunk(cls, params, cfg, x, cache, valid_len=None):
        """Process one prompt chunk *continuing from* ``cache`` (the serving
        engine's chunked/overlapped prefill calls this once per chunk).

        Default: ``prefill`` — correct for any mixer whose prefill resumes
        from the cache state and is position-independent, which is every
        recurrent kind (the state pytree *is* the position).  Mixers whose
        prefill depends on absolute position or ignores the incoming cache
        (RoPE attention over a KV cache) must override this to continue at
        the cached position.

        ``valid_len`` (optional scalar int32) marks a *ragged* chunk padded
        to its static size: only the first valid_len tokens are real.  An
        implementation must leave the cache exactly as if only the valid
        prefix had been processed (padded output rows may be garbage).
        Every built-in kind supports it; the default implementation cannot
        (plain ``prefill`` would fold padding into the state), so masked
        chunks are rejected here rather than silently corrupting state.
        """
        if valid_len is not None:
            raise NotImplementedError(
                f"mixer kind {cls.kind!r} does not support ragged "
                f"(valid_len-masked) prefill chunks — override "
                f"prefill_chunk to mask padded positions")
        return cls.prefill(params, cfg, x, cache)

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        raise NotImplementedError(cls.kind)

    @classmethod
    def cache_spec(cls, cfg, batch: int, max_len: int) -> CacheSpec:
        raise NotImplementedError(cls.kind)

    @classmethod
    def init_cache(cls, cfg, batch: int, max_len: int):
        return cls.cache_spec(cfg, batch, max_len).zeros()

    @classmethod
    def checkpoint_spec(cls, cfg, batch: int, max_len: int) -> CacheSpec:
        """Per-slot rollback image for speculative decode: the state copy
        the verify program restores a slot from when its draft suffix is
        rejected (one extra state copy per slot, the cost ROADMAP calls
        out).  Default: the full ``cache_spec`` — decode mutates every
        leaf destructively (a rolling-window KV insert overwrites the
        wrapped position; length meta alone cannot recover it), so a
        partial checkpoint would be unsound.  A mixer whose decode
        provably leaves some leaves untouched may narrow this, but the
        tree *structure* must stay identical to ``cache_spec`` — the
        verify program's conditional commit selects between run-ahead and
        committed trees leaf-by-leaf."""
        return cls.cache_spec(cfg, batch, max_len)

    # ---- analytical decode model (consumed by core.intensity) ----------

    @classmethod
    def decode_flops(cls, cfg, seq: int) -> float:
        """Per-token mixer FLOPs at decode (batch 1)."""
        raise NotImplementedError(cls.kind)

    @classmethod
    def decode_token_bytes(cls, cfg) -> float:
        """Per-token activation I/O (q/k/v/o projections etc.)."""
        raise NotImplementedError(cls.kind)

    @classmethod
    def param_count(cls, cfg) -> int:
        """Mixer parameter count per layer (sharding/footprint planning)."""
        raise NotImplementedError(cls.kind)
