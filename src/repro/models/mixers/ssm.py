"""ssm mixer kind — Mamba-2 / SSD, wrapping ``repro.models.ssm``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import ssm as ssm_layer
from repro.models.mixers import register
from repro.models.mixers.base import ArraySpec, CacheSpec, SequenceMixer

_CONV_W = ssm_layer.CONV_WIDTH


@register
class SSD(SequenceMixer):
    kind = "ssm"
    supports_ragged_prefill = True
    supports_batched_ragged_prefill = True   # per-row (B,) valid_len
    state_passes = 2           # S <- g*S + B x^T : one read + one write

    @classmethod
    def init_params(cls, key, cfg, dtype):
        return ssm_layer.init_ssm(key, cfg.d_model, cfg.ssm_d_inner,
                                  cfg.ssm_headdim, cfg.ssm_d_state,
                                  dtype=dtype)

    @classmethod
    def train(cls, params, cfg, x):
        return ssm_layer.ssm_train(params, x, d_inner=cfg.ssm_d_inner,
                                   headdim=cfg.ssm_headdim,
                                   d_state=cfg.ssm_d_state)

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        return ssm_layer.ssm_prefill(params, x, cache,
                                     d_inner=cfg.ssm_d_inner,
                                     headdim=cfg.ssm_headdim,
                                     d_state=cfg.ssm_d_state,
                                     use_pallas=cfg.use_pallas_serving)

    @classmethod
    def prefill_chunk(cls, params, cfg, x, cache, valid_len=None):
        # ragged chunks: S masked in the kernel / pre-masked inputs, conv
        # carries sliced at the valid boundary
        return ssm_layer.ssm_prefill(params, x, cache,
                                     d_inner=cfg.ssm_d_inner,
                                     headdim=cfg.ssm_headdim,
                                     d_state=cfg.ssm_d_state,
                                     use_pallas=cfg.use_pallas_serving,
                                     valid_len=valid_len)

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        return ssm_layer.ssm_decode(params, x_t, cache,
                                    d_inner=cfg.ssm_d_inner,
                                    headdim=cfg.ssm_headdim,
                                    d_state=cfg.ssm_d_state,
                                    use_pallas=cfg.use_pallas_serving)

    @classmethod
    def cache_spec(cls, cfg, batch, max_len):
        nheads = cfg.ssm_d_inner // cfg.ssm_headdim
        act = jnp.dtype(cfg.act_dtype)
        return CacheSpec(ssm_layer.SSMState(
            S=ArraySpec((batch, nheads, cfg.ssm_d_state, cfg.ssm_headdim),
                        jnp.dtype(cfg.state_dtype), "state"),
            conv_x=ArraySpec((batch, _CONV_W - 1, cfg.ssm_d_inner), act,
                             "state"),
            conv_B=ArraySpec((batch, _CONV_W - 1, cfg.ssm_d_state), act,
                             "state"),
            conv_C=ArraySpec((batch, _CONV_W - 1, cfg.ssm_d_state), act,
                             "state")))

    @classmethod
    def decode_flops(cls, cfg, seq):
        nheads = cfg.ssm_d_inner // cfg.ssm_headdim
        return nheads * 5.0 * cfg.ssm_d_state * cfg.ssm_headdim

    @classmethod
    def decode_token_bytes(cls, cfg):
        w = jnp.dtype(cfg.act_dtype).itemsize
        nheads = cfg.ssm_d_inner // cfg.ssm_headdim
        return nheads * (2 * cfg.ssm_d_state + 2 * cfg.ssm_headdim) * w

    @classmethod
    def param_count(cls, cfg):
        d = cfg.d_model
        return (d * cfg.ssm_d_inner * 3 + 2 * d * cfg.ssm_d_state
                + d * (cfg.ssm_d_inner // cfg.ssm_headdim))
