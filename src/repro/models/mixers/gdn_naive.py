"""gdn_naive mixer kind — Gated DeltaNet with the Alg. 1 three-pass decode
step from ``repro.core.gdn`` (retrieval, update, output as separate passes
over S).  Parameters, train and prefill are identical to ``gdn``; only the
decode datapath differs.  Registered as the sixth kind purely as parity
proof for the registry ("adding a mixer is one module, zero lm.py edits")
and as the HBM-round-trip baseline in the intensity model
(``state_passes=4``: three reads + one write, paper Table II's GPU row).
"""
from __future__ import annotations

from repro.models.mixers import register
from repro.models.mixers.gdn import GatedDeltaNet


@register
class GatedDeltaNetNaive(GatedDeltaNet):
    kind = "gdn_naive"
    state_passes = 4           # Alg. 1: 3 read passes + 1 write pass
    fused = False
