"""attn / swa mixer kinds — softmax attention over a (possibly rolling)
KV cache, wrapping ``repro.models.attention``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import attention
from repro.models.mixers import register
from repro.models.mixers.base import ArraySpec, CacheSpec, SequenceMixer


def _head_mask(cfg):
    if not cfg.n_heads_pad and not cfg.n_kv_heads_pad:
        return None
    return jnp.asarray(cfg.head_mask())


@register
class Attention(SequenceMixer):
    kind = "attn"
    is_attention = True
    supports_ragged_prefill = True
    supports_batched_ragged_prefill = True   # per-row (B,) valid_len
    quadratic = True           # O(T) KV — no fixed-size persistent state
    state_passes = 0

    @classmethod
    def _window(cls, cfg):
        return None

    @classmethod
    def init_params(cls, key, cfg, dtype):
        return attention.init_attention(key, cfg.d_model, cfg.hq_eff,
                                        cfg.hkv_eff, cfg.head_dim, dtype)

    @classmethod
    def train(cls, params, cfg, x):
        return attention.attn_train(params, x, rope_theta=cfg.rope_theta,
                                    window=cls._window(cfg),
                                    use_flash_kernel=cfg.use_flash_kernel,
                                    head_mask=_head_mask(cfg))

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        return attention.attn_prefill(params, x, cache,
                                      rope_theta=cfg.rope_theta,
                                      window=cls._window(cfg),
                                      head_mask=_head_mask(cfg))

    @classmethod
    def prefill_chunk(cls, params, cfg, x, cache, valid_len=None):
        # positions and visibility continue from cache.length (the base-class
        # default would restart RoPE at 0 and drop the cached KV); ragged
        # chunks skip the rolling insert of padded positions and advance
        # length by valid_len only
        return attention.attn_prefill_chunk(params, x, cache,
                                            rope_theta=cfg.rope_theta,
                                            window=cls._window(cfg),
                                            head_mask=_head_mask(cfg),
                                            valid_len=valid_len)

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        return attention.attn_decode_xla(params, x_t, cache,
                                         rope_theta=cfg.rope_theta,
                                         window=cls._window(cfg),
                                         head_mask=_head_mask(cfg))

    @classmethod
    def cache_spec(cls, cfg, batch, max_len):
        w = cls._window(cfg)
        size = max_len if w is None else min(w, max_len)
        dtype = jnp.dtype(cfg.act_dtype)
        kv = (batch, cfg.hkv_eff, size, cfg.head_dim)
        return CacheSpec(attention.KVCache(
            k=ArraySpec(kv, dtype, "window"),
            v=ArraySpec(kv, dtype, "window"),
            length=ArraySpec((batch,), jnp.int32, "meta")))

    @classmethod
    def decode_flops(cls, cfg, seq):
        w = cls._window(cfg)
        eff = seq if w is None else min(w, seq)
        return 2.0 * cfg.hq_eff * cfg.head_dim * eff * 2   # qk^T and pv

    @classmethod
    def decode_token_bytes(cls, cfg):
        w = jnp.dtype(cfg.act_dtype).itemsize
        return (2 * cfg.hq_eff * cfg.head_dim
                + 2 * cfg.hkv_eff * cfg.head_dim) * w

    @classmethod
    def param_count(cls, cfg):
        d = cfg.d_model
        return (d * cfg.head_dim * (cfg.n_heads + 2 * cfg.n_kv_heads)
                + cfg.n_heads * cfg.head_dim * d)


@register
class SlidingWindowAttention(Attention):
    kind = "swa"
    quadratic = False          # rolling window: O(window) state

    @classmethod
    def _window(cls, cfg):
        return cfg.window
