"""rglru mixer kind — RG-LRU diagonal vector-state recurrence
(RecurrentGemma), wrapping ``repro.models.rglru``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import rglru as rglru_layer
from repro.models.mixers import register
from repro.models.mixers.base import ArraySpec, CacheSpec, SequenceMixer

_CONV_W = rglru_layer.CONV_WIDTH


@register
class RGLRU(SequenceMixer):
    kind = "rglru"
    supports_ragged_prefill = True
    supports_batched_ragged_prefill = True   # per-row (B,) valid_len
    state_passes = 2           # h <- a*h + b : one read + one write

    @classmethod
    def init_params(cls, key, cfg, dtype):
        return rglru_layer.init_rglru(key, cfg.d_model, cfg.rglru_width,
                                      dtype=dtype)

    @classmethod
    def train(cls, params, cfg, x):
        return rglru_layer.rglru_train(params, x)

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        return rglru_layer.rglru_prefill(params, x, cache)

    @classmethod
    def prefill_chunk(cls, params, cfg, x, cache, valid_len=None):
        # ragged chunks: padded gates forced to identity, conv carry
        # sliced at the valid boundary
        return rglru_layer.rglru_prefill(params, x, cache,
                                         valid_len=valid_len)

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        return rglru_layer.rglru_decode(params, x_t, cache)

    @classmethod
    def cache_spec(cls, cfg, batch, max_len):
        return CacheSpec(rglru_layer.RGLRUState(
            h=ArraySpec((batch, cfg.rglru_width), jnp.float32, "state"),
            conv=ArraySpec((batch, _CONV_W - 1, cfg.rglru_width),
                           jnp.dtype(cfg.act_dtype), "state")))

    @classmethod
    def decode_flops(cls, cfg, seq):
        return 8.0 * cfg.rglru_width

    @classmethod
    def decode_token_bytes(cls, cfg):
        return 3 * cfg.rglru_width * jnp.dtype(cfg.act_dtype).itemsize

    @classmethod
    def param_count(cls, cfg):
        d, w = cfg.d_model, cfg.rglru_width
        return 2 * d * w + 2 * w * w + w * d
