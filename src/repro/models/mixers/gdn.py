"""gdn mixer kind — Gated DeltaNet, the paper's primitive, wrapping
``repro.models.gdn_layer``."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models import gdn_layer
from repro.models.mixers import register
from repro.models.mixers.base import ArraySpec, CacheSpec, SequenceMixer


@register
class GatedDeltaNet(SequenceMixer):
    kind = "gdn"
    supports_ragged_prefill = True
    supports_batched_ragged_prefill = True   # per-row (B,) valid_len
    state_passes = 2           # fused Alg. 2: one read + one write pass
    fused = True               # decode algorithm (Alg. 2 vs Alg. 1)

    @classmethod
    def init_params(cls, key, cfg, dtype):
        return gdn_layer.init_gdn(key, cfg.d_model, cfg.gdn_k_heads,
                                  cfg.gdn_v_heads, cfg.gdn_head_dim, dtype)

    @classmethod
    def train(cls, params, cfg, x):
        return gdn_layer.gdn_train(params, x)

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        return gdn_layer.gdn_prefill(params, x, cache,
                                     use_pallas=cfg.use_pallas_serving)

    @classmethod
    def prefill_chunk(cls, params, cfg, x, cache, valid_len=None):
        # state-resuming prefill + ragged masking (padded tokens are an
        # exact no-op on S inside the kernel / pre-masked on the XLA path)
        return gdn_layer.gdn_prefill(params, x, cache,
                                     use_pallas=cfg.use_pallas_serving,
                                     valid_len=valid_len)

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        return gdn_layer.gdn_decode(params, x_t, cache,
                                    use_pallas=cfg.use_pallas_serving,
                                    fused=cls.fused)

    @classmethod
    def cache_spec(cls, cfg, batch, max_len):
        hd = cfg.gdn_head_dim
        return CacheSpec(gdn_layer.GDNState(
            S=ArraySpec((batch, cfg.gdn_v_heads, hd, hd),
                        jnp.dtype(cfg.state_dtype), "state")))

    @classmethod
    def decode_flops(cls, cfg, seq):
        d = cfg.gdn_head_dim
        return cfg.gdn_v_heads * (7.0 * d * d + 8.0 * d)

    @classmethod
    def decode_token_bytes(cls, cfg):
        w = jnp.dtype(cfg.act_dtype).itemsize
        d = cfg.gdn_head_dim
        return (2 * cfg.gdn_k_heads * d + 2 * cfg.gdn_v_heads * d
                + 2 * cfg.gdn_v_heads) * w

    @classmethod
    def param_count(cls, cfg):
        d, hd = cfg.d_model, cfg.gdn_head_dim
        return (d * hd * (2 * cfg.gdn_k_heads + cfg.gdn_v_heads)
                + cfg.gdn_v_heads * hd * d + 2 * d * cfg.gdn_v_heads)
