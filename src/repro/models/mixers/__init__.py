"""Registry of SequenceMixer implementations.

One mixer kind == one module implementing the ``SequenceMixer`` protocol and
decorated with ``@register``.  The unified LM (``repro.models.lm``), the
serving engine, the sharding planner and the intensity model all consume
mixers exclusively through ``get_mixer(kind)`` — adding a new kind is a
one-module change plus an import below (or a ``register`` call from anywhere,
e.g. a test or a plugin).
"""
from __future__ import annotations

from typing import Dict, Type

from repro.models.mixers.base import (ArraySpec, CacheSpec, SequenceMixer)

MIXERS: Dict[str, Type[SequenceMixer]] = {}


def register(cls: Type[SequenceMixer]) -> Type[SequenceMixer]:
    """Class decorator: make ``cls`` available as ``get_mixer(cls.kind)``."""
    if not cls.kind:
        raise ValueError(f"{cls.__name__} has no `kind`")
    MIXERS[cls.kind] = cls
    return cls


def get_mixer(kind: str) -> Type[SequenceMixer]:
    try:
        return MIXERS[kind]
    except KeyError:
        raise KeyError(f"unknown mixer kind {kind!r}; registered: "
                       f"{sorted(MIXERS)}") from None


# Built-in kinds self-register on import.
from repro.models.mixers import attn as _attn            # noqa: E402,F401
from repro.models.mixers import gdn as _gdn              # noqa: E402,F401
from repro.models.mixers import gdn_naive as _gdn_naive  # noqa: E402,F401
from repro.models.mixers import ssm as _ssm              # noqa: E402,F401
from repro.models.mixers import rglru as _rglru          # noqa: E402,F401

__all__ = ["ArraySpec", "CacheSpec", "SequenceMixer", "MIXERS",
           "register", "get_mixer"]
