from repro.core import gdn, intensity
from repro.core.gdn import (
    gates,
    log_gate,
    decode_step_naive,
    decode_step_fused,
    ssd_decode_step,
    prefill_sequential,
    prefill_chunkwise,
    gdn_decode,
    gdn_prefill,
)

__all__ = [
    "gdn",
    "intensity",
    "gates",
    "log_gate",
    "decode_step_naive",
    "decode_step_fused",
    "ssd_decode_step",
    "prefill_sequential",
    "prefill_chunkwise",
    "gdn_decode",
    "gdn_prefill",
]
