"""Arithmetic-intensity model for batch-1 decode (paper Fig. 1 + Table II).

Counts per-token FLOPs and off-chip bytes for the *mixer* primitive of each
architecture family, at batch 1, FP32 state (paper convention).  This is the
analytical model used to reproduce the paper's claims:

  * GQA/MHSA transformer decode  ~  1 FLOP/B
  * GDN / DeltaNet / Mamba-2     <  1 FLOP/B  (more memory-bound)
  * ours (persistent state)      ~ 88 FLOP/B  (state I/O eliminated)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Profile:
    name: str
    flops: float          # per token, mixer only
    state_bytes: float    # recurrent state / KV traffic per token (off-chip)
    token_bytes: float    # per-token input/output traffic

    @property
    def total_bytes(self) -> float:
        return self.state_bytes + self.token_bytes

    @property
    def intensity(self) -> float:
        return self.flops / self.total_bytes


def gdn_profile(h_v=32, h_k=16, d=128, w=4, persistent=False,
                fused=True) -> Profile:
    """Paper's GDN layer (Qwen3-Next config): h_v d x d state matrices.

    FLOPs per head (fused Alg. 2):
      read pass (r and S^T q):  2 * 2 * d^2      (two d x d mat-vecs)
      delta + output correct :  ~6 d
      write pass (rank-1 upd): 3 * d^2           (mul + mul + add)
    ~= 7 d^2 per v-head  -> h_v * 7 d^2 ~= 3.7 M;  with q^T k etc ~= 4.2 M
    (paper reports ~4.2 MFLOPs / token for the full layer).
    """
    flops = h_v * (7 * d * d + 8 * d)
    if persistent:
        state = 0.0
    else:
        # naive GPU reference: 3 read passes + 1 write; fused: 1 read + 1 write
        n_read = 1 if fused else 3
        state = (n_read + 1) * h_v * d * d * w
    token = (2 * h_k * d + 2 * h_v * d + 2 * h_v) * w  # q,k,v,o,gates
    return Profile("gdn", flops, state, token)


def gqa_profile(h_q=32, h_kv=8, d=128, seq=4096, w=2) -> Profile:
    """GQA softmax-attention decode: read the KV cache once per token."""
    flops = 2 * h_q * d * seq * 2           # qk^T and pv
    state = 2 * h_kv * d * seq * w          # K and V read
    state += 2 * h_kv * d * w               # append one kv
    token = (2 * h_q * d + 2 * h_kv * d) * w
    return Profile("gqa", flops, state, token)


def mamba2_profile(nheads=64, d_head=64, d_state=128, w=4,
                   persistent=False) -> Profile:
    """SSD decode: state (nheads, d_state, d_head); S = a S + B x^T; y = C^T S."""
    flops = nheads * (5 * d_state * d_head)
    state = 0.0 if persistent else 2 * nheads * d_state * d_head * w
    token = nheads * (2 * d_state + 2 * d_head) * w
    return Profile("mamba2", flops, state, token)


def rglru_profile(width=2560, w=4, persistent=False) -> Profile:
    """RG-LRU: elementwise diagonal recurrence over a vector state."""
    flops = 8 * width
    state = 0.0 if persistent else 2 * width * w
    token = 3 * width * w
    return Profile("rglru", flops, state, token)


# ---------------------------------------------------------------------------
# Spec-driven profiles: derive state bytes / intensity for a *config* from
# the same declarative `cache_spec` the model and serving engine are built
# on (single source of truth — no per-kind byte formulas duplicated here).
# ---------------------------------------------------------------------------

def mixer_cache_spec(cfg, kind: str, *, batch: int = 1, max_len: int = 4096):
    """The declarative cache spec of one mixer layer of `cfg`."""
    from repro.models.mixers import get_mixer
    return get_mixer(kind).cache_spec(cfg, batch, max_len)


def mixer_state_bytes(cfg, kind: str) -> int:
    """Fixed-size persistent recurrent state of one layer (batch 1)."""
    return mixer_cache_spec(cfg, kind).state_bytes


def arch_state_bytes(cfg) -> int:
    """Whole-model persistent-state budget (batch 1) — the paper's Eq. 8
    'does the state fit on chip' precondition, summed over layers."""
    return sum(mixer_state_bytes(cfg, k) for k in cfg.layer_kinds)


def mixer_decode_profile(cfg, kind: str, *, seq: int = 4096,
                         persistent: bool = False) -> Profile:
    """Batch-1 decode profile of one mixer layer of `cfg`.

    Off-chip state traffic = `state_passes` (declared by the mixer: reads +
    writes per token on a round-trip backend) x the spec's state bytes, plus
    one read of any context-sized window/KV buffers.  `persistent=True`
    zeroes the fixed-state term (the paper's accelerator), leaving only the
    irreducible window/KV and token I/O.
    """
    from repro.models.mixers import get_mixer
    m = get_mixer(kind)
    spec = m.cache_spec(cfg, 1, seq)
    state = 0.0 if persistent else float(m.state_passes * spec.state_bytes)
    state += float(spec.window_bytes)       # KV / rolling window read
    return Profile(kind, float(m.decode_flops(cfg, seq)), state,
                   float(m.decode_token_bytes(cfg)))


def arch_decode_profile(cfg, *, seq: int = 4096,
                        persistent: bool = False) -> Profile:
    """Whole-model batch-1 decode profile: per-layer profiles summed over
    the cycled pattern."""
    ps = [mixer_decode_profile(cfg, k, seq=seq, persistent=persistent)
          for k in cfg.layer_kinds]
    return Profile(cfg.name, sum(p.flops for p in ps),
                   sum(p.state_bytes for p in ps),
                   sum(p.token_bytes for p in ps))


def mixer_checkpoint_bytes(cfg, kind: str, *, max_len: int = 4096) -> int:
    """Per-slot speculative-rollback image of one layer — straight from
    the mixer's declarative ``checkpoint_spec`` (default: the full cache
    spec, i.e. one extra state copy per slot)."""
    from repro.models.mixers import get_mixer
    return get_mixer(kind).checkpoint_spec(cfg, 1, max_len).nbytes


def arch_checkpoint_bytes(cfg, *, max_len: int = 4096) -> int:
    """Whole-model per-slot checkpoint budget, summed over layers."""
    return sum(mixer_checkpoint_bytes(cfg, k, max_len=max_len)
               for k in cfg.layer_kinds)


def speculative_decode_profile(cfg, *, k_draft: int, acceptance: float,
                               draft_cfg=None, seq: int = 4096,
                               persistent: bool = False) -> Profile:
    """Analytical per-*emitted*-token decode profile under draft–verify
    speculative decoding.

    A speculative tick runs the target datapath over k_draft + 1
    positions, the draft over 2 * k_draft + 1 (k_draft proposal steps
    plus the teacher-forced re-run inside the verify), and one
    checkpoint-buffer copy (a read + a write of the rollback image, the
    ``arch_checkpoint_bytes`` cost the cache-spec declaration
    propagates here).  It emits 1 + acceptance * k_draft tokens, so the
    per-emitted-token cost is the tick totals divided by that.  Note the
    target's state traffic per emitted token does NOT shrink (every
    verify position is a state pass) — what speculative decode amortizes
    is the *host sync* and per-tick scheduling overhead, by up to
    k_draft + 1 tokens per sync; the checkpoint makes that cost one
    state copy instead of a replay pass.

    ``acceptance`` is the per-drafted-token acceptance rate in [0, 1]
    (the scheduler's ``acceptance_rate`` metric).  ``draft_cfg``
    defaults to ``cfg`` (self-draft)."""
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError(f"acceptance must be in [0, 1], got {acceptance}")
    if k_draft < 0:
        raise ValueError(f"k_draft must be >= 0, got {k_draft}")
    if draft_cfg is None:
        draft_cfg = cfg
    target = arch_decode_profile(cfg, seq=seq, persistent=persistent)
    draft = arch_decode_profile(draft_cfg, seq=seq, persistent=persistent)
    ckpt = 2.0 * arch_checkpoint_bytes(cfg, max_len=seq)   # read + write
    emitted = 1.0 + acceptance * k_draft
    positions = k_draft + 1
    flops = (target.flops * positions
             + draft.flops * (2 * k_draft + 1)) / emitted
    state = (target.state_bytes * positions
             + draft.state_bytes * (2 * k_draft + 1) + ckpt) / emitted
    token = (target.token_bytes * positions
             + draft.token_bytes * (2 * k_draft + 1)) / emitted
    return Profile(f"{cfg.name}+spec(k={k_draft})", flops, state, token)


def paper_table2() -> dict:
    """Reproduce paper Table II (h_v=32, d=128, FP32)."""
    gpu = gdn_profile(persistent=False, fused=False)
    ours = gdn_profile(persistent=True)
    return {
        "gpu": {"flops": gpu.flops, "state_bytes": gpu.state_bytes,
                "token_bytes": gpu.token_bytes,
                "intensity": gpu.intensity},
        "ours": {"flops": ours.flops, "state_bytes": 0.0,
                 "token_bytes": ours.token_bytes,
                 "intensity": ours.intensity},
    }


def fig1_intensities() -> dict:
    """Batch-1 decode intensity by family (paper Fig. 1 ordering)."""
    return {
        "mhsa_gqa": gqa_profile().intensity,
        "gdn": gdn_profile(persistent=False, fused=False).intensity,
        "mamba2": mamba2_profile().intensity,
        "gdn_ours_persistent": gdn_profile(persistent=True).intensity,
    }
