"""Gated DeltaNet (GDN) recurrence — the paper's core primitive.

Implements, in pure JAX:

  * gates (paper Eqs. 5-6):      g_t = exp(-sigma(alpha_t) * exp(A_log) * softplus(dt_bias))
                                 beta_t = sigma(b_t)
  * naive decode step (Alg. 1):  3 passes over the d_k x d_v state S
  * fused decode step (Alg. 2):  1 read + 1 write pass, via the identity
                                 S_t^T q = g * S_{t-1}^T q + (q^T k) * dv
  * sequential prefill:          lax.scan of the decode step over tokens (oracle)
  * chunkwise-parallel prefill:  gated UT/WY transform, log-space decay ratios
                                 (train/prefill path; O(T/C) sequential steps)

Shape conventions (single head):
  q, k          : (d_k,)
  v             : (d_v,)
  S             : (d_k, d_v)   -- state; retrieval r = S^T k  in (d_v,)
  g, beta       : scalars

Batched wrappers take (B, H, ...) leading axes. Grouped Value Attention (GVA):
h_v = R * h_k value heads; q/k head j serves v-heads j*R..(j+1)*R-1.

The mamba2 / SSD family is the delta_rule=False degenerate case (u_t = v_t,
no correction term), exposed via the same chunkwise/sequential entry points.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Gates (paper Eqs. 5-6)
# ---------------------------------------------------------------------------

def softplus(x):
    return jax.nn.softplus(x)


def log_gate(alpha, A_log, dt_bias):
    """log g_t = -sigma(alpha_t) * exp(A_log) * softplus(dt_bias).  <= 0 always."""
    return -jax.nn.sigmoid(alpha) * jnp.exp(A_log) * softplus(dt_bias)


def gates(alpha, b, A_log, dt_bias):
    """Paper Eqs. (5)-(6). Returns (g, beta), both in (0, 1)."""
    g = jnp.exp(log_gate(alpha, A_log, dt_bias))
    beta = jax.nn.sigmoid(b)
    return g, beta


# ---------------------------------------------------------------------------
# Single-head decode steps
# ---------------------------------------------------------------------------

def decode_step_naive(q, k, v, S, g, beta, *, scale=None):
    """Alg. 1 — three logical passes over S (retrieval, update, output)."""
    d_k = q.shape[-1]
    scale = (1.0 / math.sqrt(d_k)) if scale is None else scale
    r = S.T @ k                        # pass 1 (read)
    dv = beta * (v - r)                # delta correction
    S_new = g * S + jnp.outer(k, dv)   # pass 2 (read+write)
    o = scale * (S_new.T @ q)          # pass 3 (read)
    return o, S_new


def decode_step_fused(q, k, v, S, g, beta, *, scale=None):
    """Alg. 2 — one read pass (computing r and o_hat together) + one write pass.

    The read pass stacks [k, q] into a single (2, d_k) @ (d_k, d_v) matmul:
    on TPU this is one MXU operation over a single traversal of S — the
    direct analogue of the paper's shared-read-pass datapath.
    """
    d_k = q.shape[-1]
    scale = (1.0 / math.sqrt(d_k)) if scale is None else scale
    kq = jnp.stack([k, q])             # (2, d_k)
    rr = kq @ S                        # (2, d_v): rr[0] = S^T k, rr[1] = S^T q
    r, sq = rr[0], rr[1]
    o_hat = g * sq
    dv = beta * (v - r)
    alpha = q @ k                      # phase 1: dot product
    o = scale * (o_hat + alpha * dv)   # phase 4: output correction
    S_new = g * S + jnp.outer(k, dv)   # phase 5: single write pass
    return o, S_new


def ssd_decode_step(q, k, v, S, g, *, scale=None):
    """Mamba-2 / SSD decode: S_t = g*S + k v^T ; o = scale * S_t^T q.

    (GDN without the delta rule; shares the fused read/write structure.)
    """
    scale = 1.0 if scale is None else scale
    S_new = g * S + jnp.outer(k, v)
    o = scale * (S_new.T @ q)
    return o, S_new


# ---------------------------------------------------------------------------
# Sequential prefill (oracle) — scan the fused step over tokens
# ---------------------------------------------------------------------------

def prefill_sequential(q, k, v, log_g, beta, S0, *, scale=None,
                       delta_rule=True):
    """Token-by-token scan. q,k: (T, d_k); v: (T, d_v); log_g, beta: (T,).

    Returns O: (T, d_v), S_final: (d_k, d_v).
    """
    d_k = q.shape[-1]
    if scale is None:
        scale = (1.0 / math.sqrt(d_k)) if delta_rule else 1.0

    def step(S, inp):
        q_t, k_t, v_t, lg_t, b_t = inp
        g_t = jnp.exp(lg_t)
        if delta_rule:
            o, S_new = decode_step_fused(q_t, k_t, v_t, S, g_t, b_t,
                                         scale=scale)
        else:
            o, S_new = ssd_decode_step(q_t, k_t, v_t, S, g_t, scale=scale)
        return S_new, o

    S_final, O = jax.lax.scan(step, S0, (q, k, v, log_g, beta))
    return O, S_final


# ---------------------------------------------------------------------------
# Chunkwise-parallel prefill (gated UT transform)
# ---------------------------------------------------------------------------
#
# Within a chunk of length C with cumulative log-decay L_t = sum_{r<=t} log g_r:
#   u_t = beta_t (v_t - S_{t-1}^T k_t)
#   (I + A) U = beta ⊙ (V - gamma_prev ⊙ (K @ S0)),
#       A[t,s] = beta_t * exp(L_{t-1} - L_s) * (k_t . k_s),  s < t
#   O  = scale * (gamma ⊙ (Q @ S0) + M @ U),
#       M[t,s] = exp(L_t - L_s) * (q_t . k_s),               s <= t
#   S_C = exp(L_C) S0 + (exp(L_C - L) ⊙ K)^T @ U
#
# All decay ratios exp(L_a - L_b) have a >= b hence are <= 1: log-space is
# numerically safe for arbitrarily strong gating.

def _chunk_delta(q, k, v, log_g, beta, S0, scale):
    C, d_k = q.shape
    L = jnp.cumsum(log_g)                             # (C,)
    L_prev = L - log_g                                # L_{t-1}
    gamma = jnp.exp(L)                                # (C,)
    gamma_prev = jnp.exp(L_prev)

    kk = k @ k.T                                      # (C, C)
    decayA = jnp.exp(L_prev[:, None] - L[None, :])    # exp(L_{t-1} - L_s)
    A = beta[:, None] * decayA * kk
    A = jnp.tril(A, k=-1)                             # strictly lower

    rhs = beta[:, None] * (v - gamma_prev[:, None] * (k @ S0))   # (C, d_v)
    U = jax.scipy.linalg.solve_triangular(
        jnp.eye(C, dtype=q.dtype) + A, rhs, lower=True)

    qk = q @ k.T
    decayM = jnp.exp(L[:, None] - L[None, :])
    M = jnp.tril(decayM * qk)                         # inclusive lower
    O = scale * (gamma[:, None] * (q @ S0) + M @ U)

    w = jnp.exp(L[-1] - L)                            # (C,)
    S_new = jnp.exp(L[-1]) * S0 + (w[:, None] * k).T @ U
    return O, S_new


def _chunk_ssd(q, k, v, log_g, S0, scale):
    C, d_k = q.shape
    L = jnp.cumsum(log_g)
    gamma = jnp.exp(L)
    qk = q @ k.T
    decayM = jnp.exp(L[:, None] - L[None, :])
    M = jnp.tril(decayM * qk)
    O = scale * (gamma[:, None] * (q @ S0) + M @ v)
    w = jnp.exp(L[-1] - L)
    S_new = jnp.exp(L[-1]) * S0 + (w[:, None] * k).T @ v
    return O, S_new


def prefill_chunkwise(q, k, v, log_g, beta, S0, *, chunk=64, scale=None,
                      delta_rule=True):
    """Chunk-parallel prefill. T must be a multiple of `chunk` (pad upstream).

    q,k: (T, d_k); v: (T, d_v); log_g, beta: (T,); S0: (d_k, d_v).
    """
    T, d_k = q.shape
    chunk = min(chunk, T)
    assert T % chunk == 0, f"T={T} not a multiple of chunk={chunk}"
    n = T // chunk
    if scale is None:
        scale = (1.0 / math.sqrt(d_k)) if delta_rule else 1.0

    qs = q.reshape(n, chunk, d_k)
    ks = k.reshape(n, chunk, d_k)
    vs = v.reshape(n, chunk, -1)
    lgs = log_g.reshape(n, chunk)
    bs = beta.reshape(n, chunk)

    def step(S, inp):
        qc, kc, vc, lgc, bc = inp
        if delta_rule:
            O, S_new = _chunk_delta(qc, kc, vc, lgc, bc, S, scale)
        else:
            O, S_new = _chunk_ssd(qc, kc, vc, lgc, S, scale)
        return S_new, O

    S_final, O = jax.lax.scan(step, S0, (qs, ks, vs, lgs, bs))
    return O.reshape(T, -1), S_final


# ---------------------------------------------------------------------------
# Batched multi-head wrappers (B, H, ...) with GVA support
# ---------------------------------------------------------------------------

def gva_expand(x, n_rep: int):
    """Repeat q/k heads to match v-heads: (B, Hk, ...) -> (B, Hk*R, ...)."""
    if n_rep == 1:
        return x
    return jnp.repeat(x, n_rep, axis=1)


@partial(jax.jit, static_argnames=("fused", "scale", "delta_rule"))
def gdn_decode(q, k, v, S, g, beta, *, fused=True, scale=None,
               delta_rule=True):
    """Batched GDN decode step.

    q, k : (B, Hk, d_k);  v: (B, Hv, d_v);  S: (B, Hv, d_k, d_v)
    g, beta: (B, Hv).  Hv must be a multiple of Hk (GVA ratio R = Hv // Hk).
    delta_rule=False gives the mamba2/SSD update (beta ignored).
    Returns o: (B, Hv, d_v), S_new: (B, Hv, d_k, d_v).
    """
    B, Hk, d_k = q.shape
    Hv = v.shape[1]
    R = Hv // Hk
    qe, ke = gva_expand(q, R), gva_expand(k, R)
    if delta_rule:
        fn = decode_step_fused if fused else decode_step_naive
        fn = partial(fn, scale=scale)
        return jax.vmap(jax.vmap(fn))(qe, ke, v, S, g, beta)
    fn = partial(ssd_decode_step, scale=scale)
    return jax.vmap(jax.vmap(fn))(qe, ke, v, S, g)


@partial(jax.jit, static_argnames=("chunk", "scale", "delta_rule"))
def gdn_prefill(q, k, v, log_g, beta, S0, *, chunk=64, scale=None,
                delta_rule=True):
    """Batched chunkwise prefill.

    q, k: (B, T, Hk, d_k); v: (B, T, Hv, d_v); log_g, beta: (B, T, Hv);
    S0: (B, Hv, d_k, d_v).  Returns O: (B, T, Hv, d_v), S: (B, Hv, d_k, d_v).
    """
    B, T, Hk, d_k = q.shape
    Hv = v.shape[2]
    R = Hv // Hk
    qe = gva_expand(q.transpose(0, 2, 1, 3), R)     # (B, Hv, T, d_k)
    ke = gva_expand(k.transpose(0, 2, 1, 3), R)
    vh = v.transpose(0, 2, 1, 3)                    # (B, Hv, T, d_v)
    lgh = log_g.transpose(0, 2, 1)                  # (B, Hv, T)
    bh = beta.transpose(0, 2, 1)

    fn = partial(prefill_chunkwise, chunk=chunk, scale=scale,
                 delta_rule=delta_rule)
    O, S = jax.vmap(jax.vmap(fn))(qe, ke, vh, lgh, bh, S0)
    return O.transpose(0, 2, 1, 3), S
