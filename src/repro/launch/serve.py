"""Serving launcher: continuous-batching decode with persistent state slots.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)
    engine = DecodeEngine(cfg, params, max_slots=args.slots,
                          max_len=args.max_len, seed=args.seed)
    # per-slot budgets straight from the mixers' declarative cache specs
    print(f"engine: {args.slots} slots x "
          f"(persistent state {engine.state_bytes_per_slot / 2**10:.1f} KiB"
          f" + window/KV {engine.window_bytes_per_slot / 2**10:.1f} KiB)"
          f" = {engine.cache_bytes / 2**20:.2f} MiB slot buffers")
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 17),
                              dtype=np.int32)
        engine.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature))
    t0 = time.perf_counter()
    done = engine.run_until_done()
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s) over {engine.ticks} engine ticks")
    for r in done[:4]:
        print(f"  req {r.rid}: {list(r.output)}")


if __name__ == "__main__":
    main()
