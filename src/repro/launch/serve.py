"""Serving launcher: continuous-batching decode with persistent state slots.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b \
        --requests 8 --max-new 16 --decode-block 4 --temperature 0.8 \
        --top-k 40 --top-p 0.95

``--decode-block k`` fuses k decode+sample steps per engine tick on device
(one host sync per k tokens); sampling runs on device with per-slot
temperature / top-k / top-p.  Prefill is chunked (``--prefill-chunk``) and
by default overlapped: queued requests stream into a ring of
``--staging-depth`` staging buffers at tick boundaries while resident
slots decode, with the first token sampled on device by the fused admit
head (``--serialized`` restores the prefill-behind-a-free-slot baseline;
token streams are bitwise identical).

``--mesh DATA,MODEL`` runs each engine mesh-sharded: the slot axis is
data-parallel over DATA devices (``--slots`` is padded up to a multiple)
and the recurrent-state heads / KV context are sharded over MODEL devices
(the paper's head-parallelism axis scaled out); every tick stays one SPMD
program.  ``--engines N`` fronts N such engines with a host-side router
(``--router-policy``), each engine on its own slice of the visible
devices when enough exist.  On CPU, prefix
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to smoke-test a
topology.

``--swap-policy``/``--idle-swap-ms``/``--max-live-requests`` turn on
slot oversubscription (state paging): idle or outranked active requests
are swapped — fixed-size recurrent state + rolling KV window + sampler
row, straight from ``cache_spec`` — to host memory and resumed later
through the same slot-scatter program, bitwise-identically.  See
docs/serving.md.

``--speculative [--draft-config NAME] [--k-draft K]`` turns on
draft-verify speculative decode inside the device-resident tick: the
draft proposes K tokens per slot, one fused verify program scores them
against the target with the same per-slot sampler keys, and rejected
positions roll the recurrent state back through a per-slot checkpoint
buffer — token streams stay bitwise identical to non-speculative
decode while each accepted run costs one host sync.
``--adaptive-k-draft`` lets a windowed acceptance rate shrink/grow the
effective draft length within [1, K] — a bad draft collapses to
verify-heavy k=1 ticks instead of burning K rejected proposals per sync.

``--rpc`` puts each engine in its own worker process
(``repro.serving.rpc.EngineProxy`` over a framed pipe protocol);
``--workers N`` is shorthand for ``--rpc --engines N``.  ``--roles``
assigns per-engine roles for disaggregated serving, cycled over the
engines (e.g. ``--roles prefill,decode``): prefill engines pause every
request at the admit boundary and the router ships the swapped image to
the least-loaded compatible decode engine — decode ticks never share an
engine with prefill work, streams stay bitwise the colocated ones.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.configs.base import ServingTopology
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.serving.engine import DecodeEngine, EngineProxy, Request, Router


def _roles(args):
    """Per-engine roles, cycled over ``--roles`` (default: every engine
    serves both prefill and decode)."""
    roles = [r.strip() for r in (args.roles or "both").split(",")]
    for r in roles:
        if r not in ("prefill", "decode", "both"):
            raise SystemExit(f"--roles: unknown role {r!r} "
                             f"(prefill/decode/both)")
    return [roles[i % len(roles)] for i in range(args.engines)]


def build_engines(cfg, params, args, topo: ServingTopology):
    """One engine per ``--engines``, each on its own consecutive device
    slice when the backend has enough devices (otherwise they share the
    first slice — correct, just not physically parallel).  With
    ``--rpc`` each engine is an ``EngineProxy`` worker process instead
    (its own interpreter and jax runtime — real process parallelism);
    weights ship as the init seed, rebuilt bitwise-identically by each
    worker."""
    slots = topo.pad_slots(args.slots)
    if slots != args.slots:
        print(f"slots padded {args.slots} -> {slots} "
              f"(multiple of data={topo.data})")
    roles = _roles(args)
    common = dict(
        max_slots=slots, max_len=args.max_len,
        seed=args.seed, decode_block=args.decode_block,
        overlap=args.overlap, prefill_chunk=args.prefill_chunk,
        budget_ticks=args.budget_ticks,
        staging_depth=topo.staging_depth,
        plan_mode=args.plan_mode,
        prefill_batching=args.prefill_batching,
        prefill_budget=args.prefill_budget,
        swap_policy=args.swap_policy,
        idle_swap_ms=args.idle_swap_ms,
        max_live_requests=args.max_live_requests,
        async_paging=args.async_paging,
        gather_ring=args.gather_ring,
        host_swap_bytes=args.host_swap_bytes,
        swap_spool_dir=args.swap_spool_dir,
        speculative=args.speculative,
        draft_cfg=getattr(args, "_draft_cfg", None),
        draft_params=getattr(args, "_draft_params", None),
        k_draft=args.k_draft,
        adaptive_k=args.adaptive_k)
    engines = []
    dm = topo.devices
    if args.rpc:
        mesh_shape = None if dm == 1 else topo.shape
        for i in range(args.engines):
            print(f"spawning worker {i} (role={roles[i]})...")
            engines.append(EngineProxy(
                cfg, params_seed=args.seed, role=roles[i],
                mesh_shape=mesh_shape,
                mesh_axes=topo.axes if mesh_shape else None, **common))
        return engines, slots
    devs = jax.devices()
    shared_note = False
    for i in range(args.engines):
        lo = i * dm
        if lo + dm <= len(devs):
            sl = devs[lo:lo + dm]
        else:
            sl = devs[:dm]
            if not shared_note:
                shared_note = True
                print(f"note: engines {i}..{args.engines - 1} share "
                      f"devices 0..{dm - 1} with engine 0 (only "
                      f"{len(devs)} visible) — correct, but they "
                      f"time-slice the same hardware")
        mesh_mod.validate_mesh_shape(topo.shape, topo.axes,
                                     device_count=len(sl))
        mesh = (None if dm == 1 and args.engines == 1 else
                jax.make_mesh(topo.shape, topo.axes, devices=sl))
        engines.append(DecodeEngine(cfg, params, mesh=mesh,
                                    role=roles[i], **common))
    return engines, slots


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--decode-block", type=int, default=4,
                    help="decode+sample steps fused per engine tick "
                         "(host syncs once per block)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt chunk size for staged prefill")
    ap.add_argument("--plan-mode", default="masked",
                    choices=("masked", "pow2"),
                    help="prefill chunk planning: 'masked' (default) "
                         "dispatches one scan shape + one fixed-size "
                         "valid_len-masked tail per prompt (O(1) compile "
                         "cache); 'pow2' keeps the power-of-two tail "
                         "decomposition as the comparison baseline")
    ap.add_argument("--mesh", default="1,1",
                    help="engine mesh topology DATA,MODEL (slot axis on "
                         "data, state heads / KV context on model); "
                         "slots are padded to a multiple of DATA")
    ap.add_argument("--staging-depth", type=int, default=2,
                    help="staging-buffer ring size: ahead-of-slot "
                         "prefills outstanding under saturation")
    ap.add_argument("--no-prefill-batching", dest="prefill_batching",
                    action="store_false", default=None,
                    help="dispatch one prefill program per staged prompt "
                         "instead of fusing all staged prompts into one "
                         "batched fixed-shape program per tick (the "
                         "default batches whenever every mixer kind "
                         "supports per-row masks and the FFN is not MoE)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="per-tick prefill token budget of the batched "
                         "packer under saturation (default: every "
                         "staging row gets a full scan + admit)")
    ap.add_argument("--swap-policy", default="manual",
                    choices=("manual", "idle", "pressure", "auto"),
                    help="slot-oversubscription eviction policy: "
                         "'manual' (pause/resume/preempt API only), "
                         "'idle' (swap out active requests whose "
                         "activity lease exceeds --idle-swap-ms; touch() "
                         "renews the lease), 'pressure' (evict the "
                         "lowest-priority active request when a strictly "
                         "higher-priority request waits without a free "
                         "slot), 'auto' (both)")
    ap.add_argument("--idle-swap-ms", type=float, default=None,
                    help="activity-lease duration for --swap-policy "
                         "idle/auto: an active request untouched this "
                         "long is swapped to host, freeing its slot")
    ap.add_argument("--max-live-requests", type=int, default=None,
                    help="admission cap on LIVE sessions (queued + "
                         "staging + active + swapped) per engine — "
                         "oversubscription bounds host memory, not just "
                         "device slots (default: unlimited)")
    ap.add_argument("--async-paging", action="store_true", default=False,
                    help="overlap swap transfers with the decode tick: "
                         "swap-outs drain D2H in the background through "
                         "a ring of gather buffers (harvested at tick "
                         "boundaries) and predictable resume grants "
                         "prestage their H2D put one tick ahead — "
                         "streams stay bitwise-identical to synchronous "
                         "paging")
    ap.add_argument("--gather-ring", type=int, default=2,
                    help="device-side gather buffers for async paging: "
                         "how many swap-out drains may be outstanding "
                         "before a dispatch force-harvests the oldest "
                         "(default 2 — double buffering)")
    ap.add_argument("--host-swap-bytes", type=int, default=None,
                    help="spill watermark: when in-memory swapped images "
                         "exceed this many bytes, the coldest dormant "
                         "one spills to --swap-spool-dir (default: no "
                         "spilling unless a spool dir is set, then 0 — "
                         "spill every dormant image)")
    ap.add_argument("--swap-spool-dir", default=None,
                    help="directory for spilled swap images (wire codec) "
                         "(spill-to-disk tier for truly cold sessions; "
                         "images reload transparently on resume)")
    ap.add_argument("--engines", type=int, default=1,
                    help="number of per-mesh engines behind the router")
    ap.add_argument("--rpc", action="store_true", default=False,
                    help="run each engine in its own worker process "
                         "(EngineWorker subprocess behind an "
                         "EngineProxy) instead of in-process")
    ap.add_argument("--workers", type=int, default=None,
                    help="shorthand for --rpc --engines N")
    ap.add_argument("--roles", default=None,
                    help="comma list of per-engine roles cycled over the "
                         "engines, e.g. 'prefill,decode' for "
                         "disaggregated serving (default: every engine "
                         "is 'both')")
    ap.add_argument("--router-policy", default="least_loaded",
                    choices=("least_loaded", "round_robin"))
    ap.add_argument("--serialized", dest="overlap", action="store_false",
                    default=True,
                    help="disable prefill/decode overlap (admit prefills "
                         "behind a free slot, on the tick thread)")
    ap.add_argument("--no-budget-ticks", dest="budget_ticks",
                    action="store_false", default=True,
                    help="always run full decode-block ticks (disable the "
                         "budget-aware tick-length cap)")
    ap.add_argument("--speculative", action="store_true", default=False,
                    help="draft-verify speculative decode inside the "
                         "device tick: a draft model proposes --k-draft "
                         "tokens per slot, one fused verify program "
                         "scores them with the target and rolls "
                         "recurrent state back to the last accepted "
                         "position; token streams stay bitwise identical "
                         "to non-speculative decode")
    ap.add_argument("--draft-config", default="self",
                    help="draft model for --speculative: 'self' (default; "
                         "the target drafts for itself — acceptance "
                         "upper bound) or any registered arch name with "
                         "the same vocab (randomly initialised here; a "
                         "real deployment loads trained draft weights)")
    ap.add_argument("--k-draft", type=int, default=4,
                    help="draft tokens proposed per slot per "
                         "speculative tick (each tick emits 1..k+1 "
                         "tokens per slot on one host sync)")
    ap.add_argument("--adaptive-k-draft", dest="adaptive_k",
                    action="store_true", default=False,
                    help="acceptance-adaptive draft length: a windowed "
                         "acceptance rate shrinks/grows the effective k "
                         "within [1, --k-draft]; streams unchanged")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="device top-k sampling (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="device nucleus sampling (1.0 = disabled)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()
    if args.workers is not None:
        args.rpc = True
        args.engines = args.workers

    topo = ServingTopology.parse(args.mesh,
                                 staging_depth=args.staging_depth)
    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = lm.init_lm(jax.random.PRNGKey(args.seed), cfg)
    args._draft_cfg = args._draft_params = None
    if args.speculative and args.draft_config != "self":
        dcfg = configs.get_arch(args.draft_config)
        if args.reduced:
            dcfg = dcfg.reduced()
        if dcfg.vocab != cfg.vocab:
            raise SystemExit(f"--draft-config {args.draft_config}: vocab "
                             f"{dcfg.vocab} != target vocab {cfg.vocab}")
        args._draft_cfg = dcfg
        args._draft_params = lm.init_lm(jax.random.PRNGKey(args.seed + 1),
                                        dcfg)
    engines, slots = build_engines(cfg, params, args, topo)
    router = Router(engines, policy=args.router_policy)
    eng = engines[0]
    # per-slot budgets straight from the mixers' declarative cache specs
    print(f"topology: {args.engines} "
          f"{'worker process(es)' if args.rpc else 'engine(s)'} x mesh "
          f"data={topo.data},model={topo.model} "
          f"(staging ring depth {topo.staging_depth}, "
          f"router={args.router_policy}, "
          f"roles={','.join(_roles(args))})")
    if not args.rpc:
        print(f"engine: {slots} slots x "
              f"(persistent state "
              f"{eng.state_bytes_per_slot / 2**10:.1f} KiB"
              f" + window/KV {eng.window_bytes_per_slot / 2**10:.1f} KiB)"
              f" = {eng.cache_bytes / 2**20:.2f} MiB slot buffers, "
              f"decode_block={args.decode_block}, "
              f"prefill={'overlapped' if args.overlap else 'serialized'} "
              f"chunks of {eng.prefill_chunk} ({eng.plan_mode} plans, "
              f"{'batched' if eng.prefill_batching else 'per-prompt'} "
              f"staging)")
    if not args.rpc and (args.swap_policy != "manual"
                         or args.max_live_requests
                         or args.async_paging or args.swap_spool_dir):
        print(f"paging: swap_policy={args.swap_policy}"
              + (f", idle lease {args.idle_swap_ms:.0f} ms"
                 if args.idle_swap_ms is not None else "")
              + (f", max {args.max_live_requests} live sessions/engine"
                 if args.max_live_requests else "")
              + (f", async (gather ring {args.gather_ring})"
                 if args.async_paging else ", synchronous")
              + (f", spool {args.swap_spool_dir} @ "
                 f"{(args.host_swap_bytes or 0) / 2**20:.1f} MiB watermark"
                 if args.swap_spool_dir else "")
              + f" — {eng.executor.swap_bytes_per_slot / 2**10:.1f} "
              f"KiB/swap from cache_spec")
    if args.speculative and not args.rpc:
        ex = eng.executor
        print(f"speculative: draft={args.draft_config}, "
              f"k_draft={args.k_draft} — per slot "
              f"{ex.checkpoint_bytes_per_slot / 2**10:.1f} KiB rollback "
              f"checkpoint + {ex.draft_bytes_per_slot / 2**10:.1f} KiB "
              f"draft state "
              f"({ex.speculative_bytes / 2**20:.2f} MiB total, from "
              f"checkpoint_spec)")
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=rng.integers(4, 17),
                              dtype=np.int32)
        router.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature,
                              top_k=args.top_k, top_p=args.top_p))
    t0 = time.perf_counter()
    done = router.run_until_done()
    dt = time.perf_counter() - t0
    m = router.metrics()
    print(f"served {m['requests']} requests, {m['tokens']} tokens in "
          f"{dt:.2f}s ({m['tokens'] / dt:.1f} tok/s) over "
          f"{m['ticks']} engine ticks "
          f"(placed {m['placed']}, migrated {m['migrated']}"
          + (f", {m['handoffs']} prefill→decode handoffs"
             if m["handoffs"] else "") + ")")
    print(f"  decode: {m['decode_us_per_token']:.0f} us/token "
          f"({m['decoded_tokens']} tokens in {m['decode_s']:.2f}s, "
          f"one host sync per {args.decode_block} tokens, "
          f"{m['stage_dispatches']} staged prefill + "
          f"{m['scatter_dispatches']} scatter dispatches)")
    if args.speculative:
        print(f"  speculative: {m['drafted_tokens']} drafted / "
              f"{m['accepted_tokens']} accepted "
              f"({m['acceptance_rate']:.2f} acceptance), "
              f"{m['spec_ticks']} draft-verify ticks, "
              f"{m['syncs_per_token']:.3f} host syncs/token, "
              f"{m['draft_prefills']} draft-state rebuilds")
    print(f"  per-request means: ttft {m['mean_ttft_s'] * 1e3:.1f} ms, "
          f"latency {m['mean_latency_s'] * 1e3:.1f} ms, "
          f"{m['mean_tokens_per_s']:.1f} tok/s")
    if m["swap_outs"] or m["swapped"]:
        us_mb = (m["swap_s"] * 1e6 / (m["swap_bytes"] / 2**20)
                 if m["swap_bytes"] else 0.0)
        print(f"  paging: {m['swap_outs']} swap-outs / {m['swap_ins']} "
              f"swap-ins, {m['swap_bytes'] / 2**20:.2f} MiB moved "
              f"({us_mb:.0f} us/MiB), {m['swapped']} session(s) parked "
              f"on host at exit")
        print(f"    dispatch {m['swap_dispatch_s'] * 1e3:.2f} ms / stall "
              f"{m['swap_stall_s'] * 1e3:.2f} ms"
              + (f", {m['swap_harvests_overlapped']} overlapped + "
                 f"{m['swap_harvests_forced']} forced harvests, "
                 f"{m['swap_prefetch_hits']}/{m['swap_prefetches']} "
                 f"prefetch hits" if args.async_paging else "")
              + (f", {m['spills']} spills / {m['spill_loads']} reloads "
                 f"({m['spill_bytes'] / 2**20:.2f} MiB spooled)"
                 if args.swap_spool_dir else ""))
    for r in done[:4]:
        print(f"  req {r.rid}: ttft {r.ttft_s * 1e3:.1f} ms, "
              f"{len(r.output)} toks: {list(r.output)}")
    if args.rpc:
        for e in engines:
            e.shutdown()


if __name__ == "__main__":
    main()
