"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-next-gdn \
        --steps 200 --global-batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt

On a real cluster each host runs this under its own process index with
jax.distributed; on this CPU container it runs the same code path on the
local device mesh (reduced configs via --reduced).  Fault tolerance,
checkpoint/resume, WSD/cosine schedules and straggler logging come from
repro.runtime.trainer.
"""
from __future__ import annotations

import argparse
import logging

from repro import configs
from repro.optim import optimizers as opt
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="cosine", choices=["cosine", "wsd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="reduced same-family config (CPU-sized); full "
                         "configs are exercised via the dry-run")
    ap.add_argument("--full", dest="reduced", action="store_false")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = configs.get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # minicpm trains with WSD per its paper
    schedule = "wsd" if cfg.name == "minicpm-2b" else args.schedule
    tc = TrainerConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, microbatches=args.microbatches,
        peak_lr=args.lr, schedule=schedule, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every)
    trainer = Trainer(cfg, tc)
    history = trainer.run()
    for step, loss in history:
        print(f"step {step:6d} loss {loss:.4f}")


if __name__ == "__main__":
    main()
