"""Trip-count-aware cost analysis over optimized HLO text.

``compiled.cost_analysis()`` counts every computation ONCE — a lax.scan over
40 layers reports 1/40th of the real FLOPs/bytes/collective traffic.  This
module re-derives the three roofline inputs from the compiled per-device HLO
with while-loop bodies scaled by their ``known_trip_count``:

  * flops            — 2 * prod(result dims) * prod(contracting dims) per
                       `dot` (MXU work; elementwise VPU flops are ignored —
                       they are bandwidth-bound and show up in bytes)
  * bytes            — sum of operand + result sizes of every top-level
                       instruction in control-flow computations (roofline
                       convention: no inter-op cache reuse), excluding
                       shape-only ops and CPU-only `convert` artifacts
  * collective bytes — operand sizes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       also trip-scaled; per collective kind

Fusion-internal instructions contribute FLOPs (dots) but not bytes (they
never touch HBM); while/conditional bodies contribute both, times their
multiplier.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|"
                       r"u8|pred|s4|u4)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->", re.M)
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_OPCODE = re.compile(r"^(?:\([^)]*\)|\S+)\s+([\w\-]+)\(")
_OPND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n[":\s]+\"?(\d+)')
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "broadcast", "iota", "reshape", "convert",
               "after-all", "partition-id", "replica-id"}

# Fusions composed only of layout/convert ops. XLA:CPU materializes fp32
# upcasts + transposes of bf16 dot operands; TPU's MXU consumes bf16 with
# native layouts, so these fusions would not exist in the TPU program.
# Skipped only when skip_layout_fusions=True (the "TPU-adjusted" §Perf
# accounting — the default stays CPU-conservative).
_LAYOUT_TOKENS = {"transpose", "copy", "bitcast", "convert", "broadcast",
                  "reshape", "slice", "fusion", "wrapped"}


def _is_layout_fusion(name: str) -> bool:
    tokens = {t for t in name.replace(".", "_").split("_") if t
              and not t.isdigit()}
    return bool(tokens) and tokens <= _LAYOUT_TOKENS


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _BYTES.get(dt, 4)
    return total


def _result_dims(text: str) -> List[List[int]]:
    return [[int(d) for d in dims.split(",") if d]
            for _, dims in _SHAPE_RE.findall(text)]


@dataclass
class Instr:
    name: str
    opcode: str
    result_bytes: int
    result_text: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR.match(line)
        if hdr and line.rstrip().endswith("{"):
            current = Computation(hdr.group(1))
            comps[current.name] = current
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        op_m = _OPCODE.match(rest)
        opcode = op_m.group(1) if op_m else rest.split("(")[0].split()[-1]
        # result type text = everything before the opcode
        result_text = rest[: rest.find(opcode)] if opcode in rest else rest
        inner = rest.split("(", 1)
        operands = _OPND.findall(inner[1].split(")", 1)[0]) \
            if len(inner) > 1 else []
        current.instrs.append(Instr(
            name=name, opcode=opcode,
            result_bytes=_shape_bytes(result_text),
            result_text=result_text, operands=operands, line=line))
    return comps


def _entry_name(hlo: str, comps: Dict[str, Computation]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
    return m.group(1) if m else next(iter(comps))


def _multipliers(hlo: str, comps: Dict[str, Computation]
                 ) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """comp -> execution multiplier; comp -> is fusion-internal."""
    entry = _entry_name(hlo, comps)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    internal: Dict[str, bool] = {c: False for c in comps}
    mult[entry] = 1.0
    # Callees are defined before callers in HLO text, so visiting
    # computations in reverse definition order is a valid topological order
    # (each caller's multiplier is final before its edges propagate).
    order = list(comps)
    if entry in order:
        order.remove(entry)
    order = [entry] + list(reversed(order))
    for cur in order:
        cm = mult[cur]
        if cm == 0.0 and cur != entry:
            continue
        for ins in comps[cur].instrs:
            callees: List[Tuple[str, float, bool]] = []
            if ins.opcode == "while":
                trip = 1.0
                t = _TRIP.search(ins.line)
                if t:
                    trip = float(t.group(1))
                b = _BODY.search(ins.line)
                c = _COND.search(ins.line)
                if b:
                    callees.append((b.group(1), trip, False))
                if c:
                    callees.append((c.group(1), trip + 1, False))
            elif ins.opcode == "fusion":
                f = _CALLS.search(ins.line)
                if f:
                    callees.append((f.group(1), 1.0, True))
            elif ins.opcode == "conditional":
                br = _BRANCHES.search(ins.line)
                if br:
                    for b in _OPND.findall(br.group(1)):
                        callees.append((b, 1.0, False))
            else:
                t = _TO_APPLY.search(ins.line)
                if t:   # reduce/sort/collective lambdas: scalar-level, skip
                    callees.append((t.group(1), 0.0, True))
                c = _CALLS.search(ins.line)
                if c:
                    callees.append((c.group(1), 1.0, ins.opcode == "fusion"))
            for callee, factor, is_internal in callees:
                if callee not in comps:
                    continue
                mult[callee] = mult.get(callee, 0.0) + cm * factor
                internal[callee] = internal.get(callee, False) or \
                    is_internal or internal.get(cur, False)
    return mult, internal


def _dot_flops(ins: Instr, shape_of: Dict[str, int],
               dims_of: Dict[str, List[List[int]]]) -> float:
    res_dims = _result_dims(ins.result_text)
    n_out = 1
    for dlist in res_dims:
        for d in dlist:
            n_out *= d
    contract = _CONTRACT.search(ins.line)
    k = 1
    if contract and ins.operands:
        lhs = ins.operands[0]
        lhs_dims = dims_of.get(lhs)
        if lhs_dims:
            flat = lhs_dims[0]
            for idx in contract.group(1).split(","):
                if idx and int(idx) < len(flat):
                    k *= flat[int(idx)]
    return 2.0 * n_out * k


def analyze(hlo: str, skip_layout_fusions: bool = False) -> dict:
    comps = parse_module(hlo)
    mult, internal = _multipliers(hlo, comps)

    shape_of: Dict[str, int] = {}
    dims_of: Dict[str, List[List[int]]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shape_of[ins.name] = ins.result_bytes
            dims_of[ins.name] = _result_dims(ins.result_text)

    flops = 0.0
    bytes_ = 0.0
    coll: Dict[str, float] = {}
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        fusion_internal = internal.get(comp.name, False)
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m * _dot_flops(ins, shape_of, dims_of)
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                nbytes = sum(shape_of.get(o, 0) for o in ins.operands)
                coll[base] = coll.get(base, 0.0) + m * nbytes
            if fusion_internal or ins.opcode in _SKIP_BYTES:
                continue
            if (skip_layout_fusions and ins.opcode == "fusion"
                    and _is_layout_fusion(ins.name)):
                continue
            if ins.opcode == "dynamic-update-slice":
                # in-place RMW of the updated region only (XLA aliases the
                # big buffer through loop carries; counting it as operand
                # traffic overstates decode-cache updates by ~the number of
                # layers)
                upd = (shape_of.get(ins.operands[1], 0)
                       if len(ins.operands) > 1 else ins.result_bytes)
                nbytes = 2 * upd
            elif ins.opcode == "dynamic-slice":
                nbytes = 2 * ins.result_bytes      # read slice + write out
            elif ins.opcode == "scatter":
                upd = (shape_of.get(ins.operands[2], 0)
                       if len(ins.operands) > 2 else ins.result_bytes)
                nbytes = 2 * upd
            elif (ins.opcode == "fusion"
                  and "dynamic-update-slice" in ins.name):
                # fused in-place update: the aliased buffer appears as both
                # the largest operand and the result; real traffic is the
                # non-aliased inputs, twice (RMW)
                ops = [shape_of.get(o, 0) for o in ins.operands]
                nbytes = 2 * (sum(ops) - (max(ops) if ops else 0))
            else:
                nbytes = ins.result_bytes + sum(shape_of.get(o, 0)
                                                for o in ins.operands)
            bytes_ += m * nbytes
    coll["total"] = sum(coll.values())
    return {"flops": flops, "bytes": bytes_, "collectives": coll}


def top_collectives(hlo: str, n: int = 20) -> list:
    """Largest (multiplier x operand bytes) collectives — hillclimbing aid."""
    comps = parse_module(hlo)
    mult, _ = _multipliers(hlo, comps)
    shape_of = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shape_of[ins.name] = ins.result_bytes
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        for ins in comp.instrs:
            base = ins.opcode.replace("-start", "")
            if base in COLLECTIVES and not ins.opcode.endswith("-done"):
                nbytes = sum(shape_of.get(o, 0) for o in ins.operands)
                rows.append((m * nbytes, m, base, ins.line.strip()[:160]))
    rows.sort(reverse=True)
    return rows[:n]


def top_bytes(hlo: str, n: int = 25) -> list:
    """Largest (multiplier x bytes) contributors — hillclimbing aid."""
    comps = parse_module(hlo)
    mult, internal = _multipliers(hlo, comps)
    shape_of = {}
    for comp in comps.values():
        for ins in comp.instrs:
            shape_of[ins.name] = ins.result_bytes
    rows = []
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0 or internal.get(comp.name, False):
            continue
        for ins in comp.instrs:
            if ins.opcode in _SKIP_BYTES:
                continue
            nbytes = ins.result_bytes + sum(shape_of.get(o, 0)
                                            for o in ins.operands)
            rows.append((m * nbytes, m, ins.opcode, comp.name,
                         ins.line.strip()[:140]))
    rows.sort(reverse=True)
    return rows[:n]
