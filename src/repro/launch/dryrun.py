import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything else follows.
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro import configs                      # noqa: E402
from repro.configs.base import SHAPES, shape_applicable   # noqa: E402
from repro.launch import hlo_cost              # noqa: E402
from repro.launch import steps as steps_mod    # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.parallel import sharding as sharding_mod       # noqa: E402

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")

# v5e hardware constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (shared by the collective term)

def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the cell (6*N*D train / 2*N_active per
    generated or prefilled token; MoE counts active params only)."""
    n_active = sharding_mod.estimate_params(cfg)
    if cfg.moe_experts:
        # replace full expert count with the active top-k experts
        expert = 3 * cfg.d_model * cfg.d_ff
        n_active -= cfg.n_layers * cfg.moe_experts * expert
        n_active += cfg.n_layers * cfg.moe_top_k * expert
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = configs.get_arch(arch)
    # §Perf variant knobs (hillclimb A/B runs)
    if os.environ.get("REPRO_STATE_DTYPE"):
        cfg = cfg.replace(state_dtype=os.environ["REPRO_STATE_DTYPE"])
    if os.environ.get("REPRO_NO_HEAD_PAD"):
        cfg = cfg.replace(n_heads_pad=0, n_kv_heads_pad=0)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lowered = steps_mod.lower_cell(cfg, shape, mesh)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    t0 = time.time()
    cost = hlo_cost.analyze(
        hlo, skip_layout_fusions=bool(os.environ.get("REPRO_TPU_ADJUSTED")))
    t_cost = time.time() - t0

    flops = cost["flops"]
    bytes_acc = cost["bytes"]
    coll = cost["collectives"]
    mflops = model_flops(cfg, shape)
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost_s": round(t_cost, 1),
        "memory": {
            # peak = max live bytes per device (the HBM-fit criterion);
            # temp = sum of all temp allocations over the program (>= peak)
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
        },
        "fits_hbm_16g": bool(
            getattr(mem, "peak_memory_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0) < 16e9),
        # hlo_cost analyses the post-SPMD per-device module
        "flops_per_device": flops,
        "bytes_per_device": bytes_acc,
        "collective_bytes_per_device": coll,
        "model_flops_global": mflops,
        "model_vs_hlo_flops": (mflops / (flops * n_chips)
                               if flops else 0.0),
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_acc / HBM_BW,
            "collective_s": coll["total"] / ICI_BW,
        },
    }
    r = result["roofline"]
    dom = max(r, key=r.get)
    result["roofline"]["dominant"] = dom
    return result


def cell_path(arch, shape_name, multi_pod):
    mesh = "multi" if multi_pod else "single"
    return os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = sorted(configs.ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(RESULTS_DIR, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                path = cell_path(arch, shape_name, multi_pod)
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {path}")
                    continue
                tag = (f"{arch} x {shape_name} x "
                       f"{'multi' if multi_pod else 'single'}")
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    res = run_cell(arch, shape_name, multi_pod)
                except Exception as e:   # noqa: BLE001
                    failures += 1
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "multi" if multi_pod else "single",
                           "status": "error", "error": str(e)[-4000:],
                           "traceback": traceback.format_exc()[-6000:]}
                    print(f"[FAIL] {tag}: {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                if res["status"] == "ok":
                    r = res["roofline"]
                    print(f"[ok] {tag}: compute {r['compute_s']*1e3:.2f}ms "
                          f"memory {r['memory_s']*1e3:.2f}ms collective "
                          f"{r['collective_s']*1e3:.2f}ms -> {r['dominant']}"
                          f" (compile {res['compile_s']}s)", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
