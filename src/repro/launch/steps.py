"""Step functions + ShapeDtypeStruct input specs for every (arch x shape) cell.

Shared by the dry-run (lower/compile only — no allocation) and the real
launchers.  For each shape kind:

  train_4k     -> train_step(state, batch): fwd + bwd + AdamW update
  prefill_32k  -> prefill_step(params, caches, tokens|embeds)
  decode_*     -> serve_step(params, caches, tokens): ONE new token against
                  a cache of seq_len (donated caches: the persistent state)

Optimizer-state dtype policy scales with arch size (bf16 / factored moments
for the 30B..480B archs) — see DESIGN.md §4.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, SHAPES, ShapeConfig
from repro.models import lm
from repro.optim import optimizers as opt
from repro.parallel import sharding
from repro.runtime import trainer as trainer_mod


def adamw_config_for(cfg: ArchConfig) -> opt.AdamWConfig:
    n = sharding.estimate_params(cfg)
    if n > 100e9:
        # Adafactor regime: factored v, no momentum — the only state that
        # fits v5e HBM at ~0.5T params (arctic-480b); see DESIGN.md §4
        return opt.AdamWConfig(moment_dtype="bfloat16", factored=True,
                               momentum=False)
    if n > 15e9:
        return opt.AdamWConfig(moment_dtype="bfloat16")
    return opt.AdamWConfig()


def microbatches_for(cfg: ArchConfig, shape: ShapeConfig, mesh,
                     budget_bytes: float = 3e9) -> int:
    """Gradient-accumulation factor sizing the per-layer activation
    checkpoints (B_local * T * d * 2 bytes * L) to ~3 GB of v5e HBM."""
    dp = sharding.axis_size(mesh, sharding.dp_axes(mesh))
    b_local = max(1, shape.global_batch // dp)
    ckpt = b_local * shape.seq_len * cfg.d_model * 2 * cfg.n_layers
    mb = 1
    while ckpt / mb > budget_bytes and mb < b_local:
        mb *= 2
    return mb


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def _tree_sds(tree):
    return jax.tree.map(lambda x: _sds(x.shape, x.dtype), tree)


# ------------------------------------------------------------------ specs

def params_sds(cfg: ArchConfig):
    return jax.eval_shape(
        lambda k: lm.init_lm(k, cfg), jax.random.PRNGKey(0))


def state_sds(cfg: ArchConfig, tc):
    return jax.eval_shape(
        lambda k: trainer_mod.init_state(k, cfg, tc), jax.random.PRNGKey(0))


def caches_sds(cfg: ArchConfig, batch: int, max_len: int):
    return jax.eval_shape(lambda: lm.init_caches(cfg, batch, max_len))


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    B, T = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.act_dtype)
    if shape.kind == "train":
        batch = {"labels": _sds((B, T), jnp.int32)}
        if cfg.frontend_stub:
            batch["embeds"] = _sds((B, T, cfg.d_model), dt)
        else:
            batch["tokens"] = _sds((B, T), jnp.int32)
        return {"batch": batch}
    if shape.kind == "prefill":
        spec = {"caches": caches_sds(cfg, B, T)}
        if cfg.frontend_stub:
            spec["embeds"] = _sds((B, T, cfg.d_model), dt)
        else:
            spec["tokens"] = _sds((B, T), jnp.int32)
        return spec
    # decode: one new token against a cache of seq_len
    return {"caches": caches_sds(cfg, B, T),
            "tokens": _sds((B,), jnp.int32)}


# ------------------------------------------------------------------ cells

def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, kwargs_of_SDS, in_shardings, out_shardings, donate)."""
    fsdp = sharding.needs_fsdp(cfg, mesh)
    pspecs = lambda tree: sharding.params_specs(                  # noqa: E731
        cfg, tree, fsdp, mesh)
    ns = lambda spec: jax.tree.map(                               # noqa: E731
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P))
    spec = input_specs(cfg, shape)

    if shape.kind == "train":
        tc = trainer_mod.TrainerConfig(
            steps=1000, seq_len=shape.seq_len,
            global_batch=shape.global_batch, adamw=adamw_config_for(cfg),
            microbatches=microbatches_for(cfg, shape, mesh),
            accum_dtype=("bfloat16"
                         if sharding.estimate_params(cfg) > 100e9
                         else "float32"))
        st = state_sds(cfg, tc)
        ps = pspecs(st["params"])
        st_spec = {
            "params": ps,
            "opt": {"mu": trainer_mod.opt_moment_specs(st["opt"]["mu"], ps),
                    "count": P()},
            "step": P(),
        }
        b_spec = sharding.batch_specs(mesh, spec["batch"])
        step = trainer_mod.build_train_step(
            cfg, tc, dp_axes=sharding.dp_axes(mesh))
        args = (st, spec["batch"])
        in_sh = (ns(st_spec), ns(b_spec))
        out_sh = (ns(st_spec), None)
        return step, args, in_sh, out_sh, (0,)

    pr = params_sds(cfg)
    ps = pspecs(pr)
    c_spec = sharding.cache_specs(cfg, mesh, spec["caches"],
                                  shape.global_batch)
    if shape.kind == "prefill":
        tok_key = "embeds" if cfg.frontend_stub else "tokens"
        tok_spec = sharding.batch_specs(mesh, {tok_key: spec[tok_key]})

        dp_act = sharding.dp_axes(mesh)
        if shape.global_batch % sharding.axis_size(mesh, dp_act) != 0:
            dp_act = None

        def prefill_step(params, caches, tok):
            kw = {"embeds": tok} if cfg.frontend_stub else {"tokens": tok}
            return lm.prefill(params, cfg, caches, dp_axes=dp_act, **kw)

        args = (pr, spec["caches"], spec[tok_key])
        dp = sharding.dp_axes(mesh)
        logits_spec = sharding.fit_spec(
            P(dp, "model"), (shape.global_batch, cfg.vocab), mesh)
        in_sh = (ns(ps), ns(c_spec), ns(tok_spec[tok_key]))
        out_sh = (ns(logits_spec), ns(c_spec))
        return prefill_step, args, in_sh, out_sh, (1,)

    # decode / serve step
    dp_act = sharding.dp_axes(mesh)
    if shape.global_batch % sharding.axis_size(mesh, dp_act) != 0:
        dp_act = None

    def serve_step(params, caches, tokens):
        return lm.decode_step(params, cfg, tokens, caches, dp_axes=dp_act)

    dp = sharding.dp_axes(mesh)
    tok_spec = sharding.fit_spec(P(dp), (shape.global_batch,), mesh)
    logits_spec = sharding.fit_spec(
        P(dp, "model"), (shape.global_batch, cfg.vocab), mesh)
    args = (pr, spec["caches"], spec["tokens"])
    in_sh = (ns(ps), ns(c_spec), ns(tok_spec))
    out_sh = (ns(logits_spec), ns(c_spec))
    return serve_step, args, in_sh, out_sh, (1,)


def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
    return lowered
