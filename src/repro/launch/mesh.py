"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.

Every constructor validates the requested shape against
``jax.device_count()`` up front (``validate_mesh_shape``) — a bad shape
used to surface as an inscrutable partitioning error deep inside the
first jit; now it raises a one-line ValueError before any program is
traced.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax


def validate_mesh_shape(shape: Sequence[int], axes: Sequence[str],
                        *, device_count: Optional[int] = None
                        ) -> Tuple[int, ...]:
    """Check a requested mesh topology before any jit sees it.

    Raises ``ValueError`` with an actionable message when the axis lists
    mismatch, an axis size is not a positive integer, or the shape needs
    more devices than the backend exposes (the common failure: forgetting
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on CPU).
    Returns the shape as a tuple on success.
    """
    shape = tuple(shape)
    axes = tuple(axes)
    if len(shape) != len(axes):
        raise ValueError(
            f"mesh shape {shape} has {len(shape)} axes but names {axes} "
            f"have {len(axes)}")
    for name, size in zip(axes, shape):
        if not isinstance(size, int) or isinstance(size, bool) or size < 1:
            raise ValueError(
                f"mesh axis {name!r} must be a positive int, got {size!r}")
    if len(set(axes)) != len(axes):
        raise ValueError(f"duplicate mesh axis names in {axes}")
    need = math.prod(shape)
    have = jax.device_count() if device_count is None else device_count
    if need > have:
        raise ValueError(
            f"mesh {dict(zip(axes, shape))} needs {need} devices but only "
            f"{have} are visible — shrink the mesh, or (CPU smoke runs) "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before the first jax import")
    return shape


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    validate_mesh_shape(shape, axes)
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    validate_mesh_shape((data, model), ("data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def make_serving_mesh(data: int = 1, model: int = 1):
    """Serving-engine mesh: slot-axis DP x head/context TP.

    Uses the first ``data * model`` visible devices (a serving host may
    dedicate the remainder to a second engine behind the router).
    """
    validate_mesh_shape((data, model), ("data", "model"))
    devs = jax.devices()[: data * model]
    return jax.make_mesh((data, model), ("data", "model"), devices=devs)
