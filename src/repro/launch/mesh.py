"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"))
