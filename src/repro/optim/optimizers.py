"""Optimizers + schedules (self-contained; no optax in this environment).

AdamW with:
  * configurable moment dtypes — bf16 first/second moments with an
    error-feedback residual buffer (distributed-optimization trick: halves
    optimizer-state HBM, the residual keeps the update unbiased over steps)
  * optional Adafactor-style factored second moment for the ~0.5T-param
    archs (arctic-480b) where even bf16 moments would not fit v5e HBM
  * global-norm clipping

Schedules: WSD (warmup-stable-decay — minicpm's schedule) and cosine.
All state lives in a pytree mirroring params, so GSPMD shards it with the
same NamedShardings (ZeRO-style when the FSDP axis is enabled).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------- schedules

def wsd_schedule(peak_lr, warmup_steps, stable_steps, decay_steps,
                 final_frac=0.1):
    """MiniCPM's warmup-stable-decay schedule."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        in_decay = jnp.clip((step - warmup_steps - stable_steps)
                            / jnp.maximum(decay_steps, 1), 0.0, 1.0)
        decay = peak_lr * (1.0 - (1.0 - final_frac) * in_decay)
        return jnp.where(step < warmup_steps, warm, decay)
    return lr


def cosine_schedule(peak_lr, warmup_steps, total_steps, final_frac=0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, step / jnp.maximum(warmup_steps, 1))
        t = jnp.clip((step - warmup_steps)
                     / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return lr


# ----------------------------------------------------------------- clipping

def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ----------------------------------------------------------------- AdamW

class AdamWConfig(NamedTuple):
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"      # "bfloat16" halves optimizer HBM
    factored: bool = False             # Adafactor-style v for huge archs
    momentum: bool = True              # False => Adafactor regime (no m/ef)
    error_feedback: bool = True        # residual buffer for bf16 moments
    clip_norm: float = 1.0


def _factored_dims(shape):
    """Last two non-trivial dims, Adafactor convention; None if ndim < 2."""
    if len(shape) < 2 or shape[-1] == 1 or shape[-2] == 1:
        return None
    return len(shape) - 2, len(shape) - 1


def init_adamw(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)

    def per_leaf(p):
        st = {"m": jnp.zeros(p.shape, mdt)} if cfg.momentum else {}
        fd = _factored_dims(p.shape) if cfg.factored else None
        if fd is not None:
            r, c = fd
            vr = list(p.shape); del vr[c]
            vc = list(p.shape); del vc[r]
            st["v_row"] = jnp.zeros(tuple(vr), jnp.float32)
            st["v_col"] = jnp.zeros(tuple(vc), jnp.float32)
        else:
            st["v"] = jnp.zeros(p.shape, mdt)
        if cfg.momentum and cfg.error_feedback and mdt != jnp.float32:
            st["ef"] = jnp.zeros(p.shape, mdt)
        return st

    return {"mu": jax.tree.map(per_leaf, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

    def per_leaf(g, st, p):
        gf = g.astype(jnp.float32)
        new_st = dict(st)
        if "m" in st:
            if "ef" in st:
                gf_m = gf + st["ef"].astype(jnp.float32)
            else:
                gf_m = gf
            m_new = cfg.b1 * st["m"].astype(jnp.float32) + (1 - cfg.b1) * gf_m
            new_st["m"] = m_new.astype(st["m"].dtype)
            if "ef" in st:   # error feedback: keep what bf16 rounding lost
                new_st["ef"] = (m_new - new_st["m"].astype(jnp.float32)
                                ).astype(st["ef"].dtype)
        else:
            m_new = gf      # momentum-free (Adafactor regime)
        if "v_row" in st:
            r, c = _factored_dims(p.shape)
            g2 = gf * gf
            vr = cfg.b2 * st["v_row"] + (1 - cfg.b2) * jnp.mean(g2, axis=c)
            vc = cfg.b2 * st["v_col"] + (1 - cfg.b2) * jnp.mean(g2, axis=r)
            new_st["v_row"], new_st["v_col"] = vr, vc
            # reconstruct v ~= vr * vc / mean(vr)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            v_hat = (jnp.expand_dims(vr / denom.squeeze(-1)[..., None], c)
                     * jnp.expand_dims(vc, r))
        else:
            v_new = cfg.b2 * st["v"].astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            new_st["v"] = v_new.astype(st["v"].dtype)
            v_hat = v_new
        m_hat = (m_new / b1c) if "m" in st else m_new
        update = m_hat / (jnp.sqrt(v_hat / b2c) + cfg.eps)
        update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return new_p, new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["mu"])
    out = []
    for g, s, p in zip(flat_g, flat_s, flat_p):
        if p.ndim >= 3 and p.size >= (1 << 26):
            # layer-stacked giants (e.g. 35 x 8 x 4864 x 448 experts): map
            # the elementwise update over the stack dim so the fp32 temps
            # are one layer, not the whole stack (v5e HBM headroom)
            out.append(jax.lax.map(
                lambda args: per_leaf(*args), (g, s, p)))
        else:
            out.append(per_leaf(g, s, p))
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    return new_params, {"mu": new_mu, "count": count}, gnorm
