"""Host-side router: one front door over one-or-more serving engines.

A single ``Scheduler``/``DecodeEngine`` owns one device mesh — one SPMD
tick program over one set of slot buffers.  Scaling past a mesh (more
hosts, more device islands, heterogeneous topologies) is a *routing*
problem, not a sharding problem: the ``Router`` fronts N engines, places
each submitted request on one of them, ticks them all, and aggregates
their metrics.  It never touches a device buffer and knows nothing about
meshes — engines are opaque behind a narrow surface (``submit`` /
``step`` / ``withdraw`` / ``load`` / the count properties below), which
an in-process ``Scheduler`` and a process-remote ``EngineProxy``
(``repro.serving.rpc``) implement interchangeably.

Placement policies:
  * ``round_robin``  — cycle over non-draining engines (uniform traffic);
  * ``least_loaded`` — engine with the fewest owed requests
    (active + queued + staging), ties to the lowest index (default).

Backlog control:
  * ``rebalance()`` — when one engine is *shard-full* (every slot busy
    AND requests queued) while another has idle capacity (free slots not
    already claimed by its own queue/staging), queued-but-not-yet-staged
    requests migrate from the fullest engine's queue tail to the idlest
    engine.  Runs automatically at every ``step``; staged/active requests
    never move (their prefill lives in device staging buffers).
  * ``drain(i)`` — stop placing on engine ``i`` and move its queued
    requests to the others (scale-down / maintenance); active and staged
    requests finish in place.  ``undrain(i)`` re-admits it.

State paging is routed too: ``pause(rid)`` / ``resume(rid)`` /
``preempt()`` find the owning engine, and rebalance is swap-aware — a
resuming request's host-side image is plain numpy in a topology-free
staging layout, so its resume *claim* can migrate from a slot-full
engine to one with idle capacity (same arch config + max_len) and be
restored through the taker's own slot scatter, re-sharded to its mesh.

**Disaggregated prefill/decode** (engine ``role``): new prompts place
only on prefill-capable engines (role ``prefill`` or ``both``).  A
``role="prefill"`` engine runs the staged prefill, pauses every request
at the admit boundary and parks the swapped image on its handoff queue;
the router's per-step handoff sweep ships each image to the
least-loaded *compatible* decode-capable engine, which readmits it
through the existing restore scatter — decode ticks never share an
engine with prefill work, and streams stay bitwise-identical to the
colocated path (the PR 7 swap guarantee).  ``pending`` counts
undelivered handoffs so ``run_until_done`` never abandons one mid-ship.

**Process-boundary engines**: ``EngineProxy`` engines tick in their own
worker process.  ``step`` issues each proxy's tick without waiting
(``step_begin``) and drains whatever replies have arrived, blocking
only when no engine made progress — so a fast decode worker keeps
ticking at its own pace while a prefill worker chews a long prompt.  A
worker that dies mid-run (EOF/broken pipe on its RPC channel) is marked
dead: its still-queued requests are re-homed to live compatible
engines, requests past the queue (their state lived in the dead
process) are marked ``"failed"``, and the router keeps serving on the
survivors.

Requests keep their original ``t_submit`` across migrations, so TTFT
measures the client's wait, not the router's shuffling.
"""
from __future__ import annotations

import warnings
from typing import Dict, List, Sequence

from repro.serving.rpc import WorkerDied
from repro.serving.scheduler import Request, Scheduler


class Router:
    """Round-robin / least-loaded front door over serving engines."""

    def __init__(self, engines: Sequence[Scheduler], *,
                 policy: str = "least_loaded"):
        if not engines:
            raise ValueError("Router needs at least one engine")
        if policy not in ("round_robin", "least_loaded"):
            raise ValueError(f"unknown placement policy {policy!r}; have "
                             f"'round_robin', 'least_loaded'")
        self.engines: List[Scheduler] = list(engines)
        self.policy = policy
        self._rr = 0                               # round-robin cursor
        self._draining = set()                     # engine indices
        self._dead = set()                         # dead worker indices
        self.placed = [0] * len(self.engines)      # submits per engine
        self.migrated = 0                          # rebalance moves
        self.handoffs = 0                          # prefill→decode ships
        self.rehomed = 0                           # dead-worker recoveries
        roles = [self._role(e) for e in self.engines]
        if any(r != "both" for r in roles):
            if all(r == "decode" for r in roles):
                raise ValueError("every engine is decode-role: nothing "
                                 "can prefill a fresh prompt")
            if ("prefill" in roles
                    and not any(r in ("decode", "both") for r in roles)):
                raise ValueError("prefill-role engines need at least one "
                                 "decode-capable engine to hand off to")

    # --------------------------------------------------------- placement
    @staticmethod
    def _role(e) -> str:
        return getattr(e, "role", "both")

    def _live(self) -> List[int]:
        live = [i for i in range(len(self.engines))
                if i not in self._draining and i not in self._dead]
        if not live:
            raise RuntimeError("all engines are draining or dead; "
                               "undrain one before submitting")
        return live

    def _prefill_capable(self) -> List[int]:
        return [i for i in self._live()
                if self._role(self.engines[i]) != "decode"]

    def _decode_capable(self) -> List[int]:
        return [i for i in self._live()
                if self._role(self.engines[i]) != "prefill"]

    def _place(self) -> int:
        live = self._prefill_capable()
        if not live:
            raise RuntimeError("no live prefill-capable engine to place "
                               "a fresh prompt on")
        if self.policy == "round_robin":
            idx = live[self._rr % len(live)]
            self._rr += 1
            return idx
        return min(live, key=lambda i: (self.engines[i].load, i))

    def submit(self, req: Request) -> int:
        """Validate + enqueue ``req`` on an engine; returns its index."""
        idx = self._place()
        self.engines[idx].submit(req)
        self.placed[idx] += 1
        return idx

    # ------------------------------------------------------ state paging
    def _owner(self, rid: int) -> int:
        for i, e in enumerate(self.engines):
            if i not in self._dead and e.owns(rid):
                return i
        raise KeyError(f"no engine owns a live request with rid {rid}")

    def pause(self, rid: int) -> Request:
        """Swap request ``rid`` out wherever it lives (see
        ``Scheduler.pause``)."""
        return self.engines[self._owner(rid)].pause(rid)

    def resume(self, rid: int) -> Request:
        """Resume a paused request on its owning engine; rebalance may
        later migrate the claim if that engine is slot-full."""
        return self.engines[self._owner(rid)].resume(rid)

    def touch(self, rid: int):
        self.engines[self._owner(rid)].touch(rid)

    # --------------------------------------------------------- rebalance
    def _compatible(self, a: int, b: int) -> bool:
        """A swapped image restores bitwise only onto an engine with the
        same arch config and context length (the cache leaves are sized
        by both); mesh shape may differ — the image is topology-free."""
        ea, eb = self.engines[a], self.engines[b]
        return ea.cfg == eb.cfg and ea.max_len == eb.max_len

    def _move(self, req: Request, donor: int, taker: int) -> bool:
        """Re-home a withdrawn request, preserving ``t_submit`` (TTFT
        measures the client's wait, not the router's shuffling).  If the
        taker rejects it (heterogeneous engines — e.g. a smaller
        ``max_len``), the request goes back on the donor's queue and the
        migration is abandoned rather than the request dropped."""
        t_submit = req.t_submit
        try:
            self.engines[taker].submit(req)
        except ValueError as e:
            self.engines[donor].readmit(req)
            req.t_submit = t_submit
            warnings.warn(f"router: engine {taker} rejected migrated "
                          f"req {req.rid} ({e}); kept on engine {donor}",
                          RuntimeWarning)
            return False
        req.t_submit = t_submit
        self.placed[taker] += 1
        self.placed[donor] -= 1
        return True

    def rebalance(self) -> int:
        """Move queued requests off shard-full engines onto idle ones
        (prefill-capable only — a queued request still needs its prompt
        run).  Returns the number of migrations."""
        moved = 0
        while True:
            capable = self._prefill_capable()
            donors = [i for i in capable
                      if self.engines[i].queue_len
                      and not self.engines[i].free_slots]
            takers = [i for i in capable
                      if self.engines[i].idle_capacity > 0]
            if not donors or not takers:
                return moved
            donor = max(donors, key=lambda i: self.engines[i].queue_len)
            taker = min(takers,
                        key=lambda i: (-self.engines[i].idle_capacity, i))
            req = self.engines[donor].withdraw()
            if req is None:             # raced empty — nothing left to move
                return moved
            if not self._move(req, donor, taker):
                return moved            # taker rejected; req is back home
            moved += 1
            self.migrated += 1

    def rebalance_swapped(self) -> int:
        """Move resume-queue claims off slot-full engines onto
        compatible decode-capable engines with idle capacity.  Returns
        the number of migrations.  Runs after ``rebalance`` at every
        multi-engine step: without it a resumed session is pinned to the
        engine that swapped it out even while a neighbor idles."""
        moved = 0
        while True:
            donors = [i for i in self._live()
                      if self.engines[i].resume_len
                      and not self.engines[i].free_slots]
            if not donors:
                return moved
            donor = max(donors,
                        key=lambda i: self.engines[i].resume_len)
            takers = [i for i in self._decode_capable()
                      if self.engines[i].idle_capacity > 0
                      and self._compatible(donor, i)]
            if not takers:
                return moved
            taker = min(takers,
                        key=lambda i: (-self.engines[i].idle_capacity, i))
            rec = self.engines[donor].withdraw_swapped()
            if rec is None:             # raced empty
                return moved
            try:
                self.engines[taker].readmit_swapped(rec)
            except ValueError as e:
                self.engines[donor].readmit_swapped(rec)
                warnings.warn(f"router: engine {taker} rejected migrated "
                              f"swapped req {rec.req.rid} ({e})",
                              RuntimeWarning)
                return moved
            self.placed[taker] += 1
            self.placed[donor] -= 1
            moved += 1
            self.migrated += 1

    # ---------------------------------------------------------- handoffs
    def dispatch_handoffs(self) -> int:
        """Ship completed-prefill swap records from prefill-role engines
        to the least-loaded compatible decode-capable engine, which
        readmits each through its own restore scatter (resume queue →
        slot grant).  Runs at every step; returns records shipped."""
        moved = 0
        for i in list(self._live()):
            eng = self.engines[i]
            if self._role(eng) != "prefill":
                continue
            while getattr(eng, "handoffs", 0) > 0:
                takers = [j for j in self._decode_capable()
                          if j != i and self._compatible(i, j)]
                if not takers:
                    warnings.warn(
                        f"router: engine {i} holds handoffs but no "
                        f"compatible decode-capable engine is live; "
                        f"leaving them parked", RuntimeWarning)
                    break
                try:
                    rec = eng.withdraw_handoff()
                except WorkerDied:
                    self._on_worker_death(i)
                    break
                if rec is None:
                    break
                taker = min(takers,
                            key=lambda j: (self.engines[j].load, j))
                try:
                    self.engines[taker].readmit_swapped(rec)
                except ValueError as e:
                    eng.readmit_swapped(rec)    # degraded: decode at home
                    warnings.warn(f"router: engine {taker} rejected "
                                  f"handoff req {rec.req.rid} ({e})",
                                  RuntimeWarning)
                    break
                self.placed[taker] += 1
                self.handoffs += 1
                moved += 1
        return moved

    def drain(self, idx: int) -> int:
        """Stop placing on engine ``idx`` and migrate its queued requests
        to the remaining engines.  Active/staged requests finish in place.
        Returns the number of requests moved."""
        if not 0 <= idx < len(self.engines):
            raise IndexError(f"no engine {idx}")
        self._draining.add(idx)
        self._live()                    # raises if nothing is left to serve
        moved = 0
        while True:
            # oldest-first: the full queue migrates in arrival order
            req = self.engines[idx].withdraw(oldest=True)
            if req is None:
                break
            if not self._move(req, idx, self._place()):
                break                   # rejected: left on the drained
                                        # engine (it still serves actives)
            moved += 1
        return moved

    def undrain(self, idx: int):
        self._draining.discard(idx)

    # ------------------------------------------------------- worker death
    def _on_worker_death(self, idx: int):
        """A worker process died (EOF/broken pipe on its RPC channel):
        mark the engine dead, re-home its still-queued requests to live
        compatible prefill-capable engines, and mark requests whose
        state lived in the dead process (staging/active/swapped) as
        ``"failed"`` — their device/host images are gone with it."""
        if idx in self._dead:
            return
        self._dead.add(idx)
        eng = self.engines[idx]
        recover = getattr(eng, "recover_queued", None)
        queued, lost = recover() if recover is not None else ([], [])
        warnings.warn(
            f"router: engine {idx} worker died — re-homing "
            f"{len(queued)} queued request(s), {len(lost)} past-queue "
            f"request(s) failed", RuntimeWarning)
        for req in queued:
            t_submit = req.t_submit
            try:
                takers = [j for j in self._prefill_capable()
                          if self._compatible(idx, j)]
            except RuntimeError:
                takers = []
            placed = False
            for j in sorted(takers,
                            key=lambda j: (self.engines[j].load, j)):
                try:
                    self.engines[j].submit(req)
                except ValueError:
                    continue
                req.t_submit = t_submit
                self.placed[j] += 1
                self.rehomed += 1
                placed = True
                break
            if not placed:
                req.state = "failed"

    def _busy(self, idx: int) -> bool:
        e = self.engines[idx]
        return e.load + getattr(e, "handoffs", 0) > 0

    def _guard(self, idx: int, fn):
        """Run ``fn(engine)``, converting a dead worker into a marked
        engine instead of an exception."""
        try:
            return fn(self.engines[idx])
        except WorkerDied:
            self._on_worker_death(idx)
            return None

    # -------------------------------------------------------------- tick
    @property
    def pending(self) -> int:
        """Requests the router still owes work to, including
        completed-prefill handoffs not yet delivered to a decode engine
        (dormant user-paused sessions are excluded, as on the engine)."""
        return sum(self.engines[i].load
                   + getattr(self.engines[i], "handoffs", 0)
                   for i in range(len(self.engines))
                   if i not in self._dead)

    def step(self):
        """One router tick: rebalance backlog (queued, then resume
        claims), tick every engine, then sweep handoffs.

        Process-remote engines tick **pipelined**: every proxy's step is
        issued up front without waiting (``step_begin``), local engines
        tick while the workers chew, and whatever replies have arrived
        are drained non-blocking — blocking only when nothing local ran
        and no reply was ready (the loop must make progress).  A proxy
        whose previous step is still in flight is simply skipped this
        round: each worker ticks at its own pace instead of the fleet
        marching in lockstep behind the slowest prefill."""
        if len(self.engines) > 1:
            self.rebalance()
            self.rebalance_swapped()
        alive = [i for i in range(len(self.engines))
                 if i not in self._dead]
        proxies = [i for i in alive
                   if hasattr(self.engines[i], "step_begin")]
        locals_ = [i for i in alive if i not in proxies]
        for i in proxies:
            self._guard(i, lambda e: e.step_begin())
        for i in locals_:
            self.engines[i].step()
        # progress = an engine that OWES work ticked; an idle worker's
        # instant replies must not let run_until_done spin through its
        # tick budget while a loaded worker is still chewing (e.g. the
        # decode worker compiling its first restore scatter)
        progressed = any(self._busy(i) for i in locals_)
        for i in proxies:
            if i in self._dead:
                continue
            busy = self._busy(i)
            if self._guard(i, lambda e: e.step_drain(block=False)) \
                    and busy:
                progressed = True
        if not progressed:
            # block for one reply from a worker that owes work so the
            # loop paces itself to the workers, not a spin
            for i in proxies:
                if i in self._dead or not self._busy(i):
                    continue
                if self._guard(i, lambda e: e.step_drain(block=True)):
                    break
        if any(self._role(self.engines[i]) == "prefill"
               for i in range(len(self.engines)) if i not in self._dead):
            self.dispatch_handoffs()

    def run_until_done(self, max_ticks: int = 10_000, *,
                       strict: bool = True) -> List[Request]:
        for _ in range(max_ticks):
            if self.pending == 0:
                break
            self.step()
        for i in range(len(self.engines)):      # settle in-flight ticks
            if i not in self._dead and hasattr(self.engines[i],
                                               "step_drain"):
                self._guard(i, lambda e: e.step_drain(block=True))
        if self.pending:
            msg = (f"Router.run_until_done: max_ticks={max_ticks} "
                   f"exhausted with {self.pending} request(s) unfinished "
                   f"across {len(self.engines)} engines")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning)
        return [r for e in self.engines for r in e.done_requests()]

    # ----------------------------------------------------------- metrics
    def reset_metrics(self):
        for i, eng in enumerate(self.engines):
            if i not in self._dead:
                self._guard(i, lambda e: e.reset_metrics())

    def metrics(self) -> Dict[str, object]:
        """Aggregate metrics over all live engines: counters summed,
        per-request means weighted by each engine's completed-request
        count, plus the per-engine dicts and the router's own placement
        counters."""
        per = []
        for i, eng in enumerate(self.engines):
            if i in self._dead:
                continue
            m = self._guard(i, lambda e: e.metrics())
            if m is not None:
                per.append(m)
        n = [m["requests"] for m in per]

        def wmean(key):
            tot = sum(n)
            if not tot:
                return 0.0
            return float(sum(m[key] * c for m, c in zip(per, n)) / tot)

        decode_s = sum(m["decode_s"] for m in per)
        decoded = sum(m["decoded_tokens"] for m in per)
        return {
            "engines": len(self.engines),
            "policy": self.policy,
            "roles": [self._role(e) for e in self.engines],
            "requests": sum(n),
            "tokens": sum(m["tokens"] for m in per),
            "ticks": sum(m["ticks"] for m in per),
            "decoded_tokens": decoded,
            "decode_s": decode_s,
            "decode_us_per_token": decode_s / max(1, decoded) * 1e6,
            "stage_dispatches": sum(m["stage_dispatches"] for m in per),
            "scatter_dispatches": sum(m["scatter_dispatches"]
                                      for m in per),
            "prefill_batching": int(all(m["prefill_batching"]
                                        for m in per)),
            "compiled_programs": sum(m["compiled_programs"] for m in per),
            "swap_outs": sum(m["swap_outs"] for m in per),
            "swap_ins": sum(m["swap_ins"] for m in per),
            "swapped": sum(m["swapped"] for m in per),
            "resuming": sum(m["resuming"] for m in per),
            "swap_s": sum(m["swap_s"] for m in per),
            "swap_bytes": sum(m["swap_bytes"] for m in per),
            "swap_dispatch_s": sum(m["swap_dispatch_s"] for m in per),
            "swap_stall_s": sum(m["swap_stall_s"] for m in per),
            "swap_prefetches": sum(m["swap_prefetches"] for m in per),
            "swap_prefetch_hits": sum(m["swap_prefetch_hits"]
                                      for m in per),
            "swap_harvests_overlapped": sum(m["swap_harvests_overlapped"]
                                            for m in per),
            "swap_harvests_forced": sum(m["swap_harvests_forced"]
                                        for m in per),
            "draining_swaps": sum(m["draining_swaps"] for m in per),
            "spills": sum(m["spills"] for m in per),
            "spill_loads": sum(m["spill_loads"] for m in per),
            "spill_bytes": sum(m["spill_bytes"] for m in per),
            "handoffs_out": sum(m["handoffs_out"] for m in per),
            "handoffs_pending": sum(m["handoffs"] for m in per),
            "speculative": int(all(m["speculative"] for m in per)),
            "spec_ticks": sum(m["spec_ticks"] for m in per),
            "drafted_tokens": sum(m["drafted_tokens"] for m in per),
            "accepted_tokens": sum(m["accepted_tokens"] for m in per),
            "acceptance_rate": (sum(m["accepted_tokens"] for m in per)
                                / max(1, sum(m["drafted_tokens"]
                                             for m in per))),
            "syncs_per_token": (sum(m["ticks"] for m in per)
                                / max(1, decoded)),
            "draft_prefills": sum(m["draft_prefills"] for m in per),
            "mean_ttft_s": wmean("mean_ttft_s"),
            "mean_latency_s": wmean("mean_latency_s"),
            "mean_tokens_per_s": wmean("mean_tokens_per_s"),
            "placed": list(self.placed),
            "migrated": self.migrated,
            "handoffs": self.handoffs,
            "rehomed": self.rehomed,
            "draining": sorted(self._draining),
            "dead": sorted(self._dead),
            "per_engine": per,
        }
