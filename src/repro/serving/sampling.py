"""On-device batched sampling for the decode engine.

The paper's argument is that decode latency is set by how often state
crosses a memory boundary.  Sampling on the host re-introduces exactly
that boundary at the serving layer: logits leave the device, a Python
loop picks a token, and the token is shipped back — one full round-trip
per token.  This module keeps the whole sample-and-check step on device
so the engine can fuse ``k`` decode+sample steps into one ``lax.scan``
(see ``lm.decode_steps``) and sync with the host once per ``k`` tokens.

Sampler state is a pytree of per-slot arrays (one row per decode slot),
living in donated device buffers next to the recurrent-state slot
buffers:

  key         (S, 2) uint32   per-slot PRNG key (folded from the request
                              id, so a request's draws are independent of
                              which slot it lands in and of ``k``)
  temperature (S,)   float32  0 => greedy (argmax of raw logits)
  top_k       (S,)   int32    0 => disabled
  top_p       (S,)   float32  1.0 => disabled
  eos_id      (S,)   int32    -1 => no EOS
  remaining   (S,)   int32    token budget left (max_new_tokens minus
                              tokens already emitted)
  done        (S,)   bool     device-side finished flag (EOS or budget)

``sample`` consumes a (S, V) logits batch and advances the state; the
filtering pipeline is: log-softmax -> temperature scale -> top-k mask ->
top-p (nucleus) mask -> Gumbel-max draw.  The same function is the fused
admit head of the chunked-prefill program (``lm.prefill_sample`` runs it
on a 1-row state over the last prompt token's logits), so the first token
never round-trips through the host either.  ``filter_logits_np`` /
``sample_np`` are the NumPy mirror of the filtering pipeline, kept as the
test reference implementation.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

SamplerState = Dict[str, jax.Array]

_NEG_INF = float("-inf")
_MIN_TEMP = 1e-6


# --------------------------------------------------------------- state

def init_state(max_slots: int) -> SamplerState:
    """All slots start done (free); ``admit_slot`` activates them."""
    return {
        "key": jnp.zeros((max_slots, 2), jnp.uint32),
        "temperature": jnp.zeros((max_slots,), jnp.float32),
        "top_k": jnp.zeros((max_slots,), jnp.int32),
        "top_p": jnp.ones((max_slots,), jnp.float32),
        "eos_id": jnp.full((max_slots,), -1, jnp.int32),
        "remaining": jnp.zeros((max_slots,), jnp.int32),
        "done": jnp.ones((max_slots,), bool),
    }


def admit_slot(state: SamplerState, slot: int, *, seed: int, rid: int,
               temperature: float, top_k: int, top_p: float,
               eos_id, budget: int) -> SamplerState:
    """Write one request's sampling parameters into slot ``slot``.

    The slot key is folded from (engine seed, request id), so the
    request's draw sequence depends only on how many tokens it has
    decoded — not on slot placement, co-resident requests, or the
    engine's ``decode_block``."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    return {
        "key": state["key"].at[slot].set(key.astype(jnp.uint32)),
        "temperature": state["temperature"].at[slot].set(
            jnp.float32(temperature)),
        "top_k": state["top_k"].at[slot].set(jnp.int32(top_k)),
        "top_p": state["top_p"].at[slot].set(jnp.float32(top_p)),
        "eos_id": state["eos_id"].at[slot].set(
            jnp.int32(-1 if eos_id is None else eos_id)),
        "remaining": state["remaining"].at[slot].set(jnp.int32(budget)),
        "done": state["done"].at[slot].set(False),
    }


def admit_row(seed, rid, temperature, top_k, top_p, eos_id,
              budget) -> SamplerState:
    """One-row sampler state for a request being admitted — the staging
    mirror of ``admit_slot``, built from (possibly traced) scalars so the
    executor's fused admit program constructs it on device in the same
    dispatch that prefills the final chunk and draws the first token
    (``eos_id`` is -1 for "no EOS").  The slot scatter then writes the
    advanced row into the slot arrays."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), rid)
    return {
        "key": key.astype(jnp.uint32)[None],
        "temperature": jnp.reshape(jnp.float32(temperature), (1,)),
        "top_k": jnp.reshape(jnp.int32(top_k), (1,)),
        "top_p": jnp.reshape(jnp.float32(top_p), (1,)),
        "eos_id": jnp.reshape(jnp.int32(eos_id), (1,)),
        "remaining": jnp.reshape(jnp.int32(budget), (1,)),
        "done": jnp.zeros((1,), bool),
    }


def admit_rows(seed, rids, temperature, top_k, top_p, eos_id,
               budget) -> SamplerState:
    """Batched ``admit_row``: (D,) parameter vectors -> a D-row sampler
    state for the executor's batched admit program.  Each row's key is
    ``fold_in(PRNGKey(seed), rids[d])`` — exactly the key ``admit_row``
    builds for that request, so a request's draw stream is independent of
    whether it was admitted alone or batched (the bitwise-parity
    guarantee of the batched staging path).  Placeholder rows (no request
    admitting this dispatch) carry whatever stale parameters the caller
    left; the caller's admit mask discards their draws."""
    base = jax.random.PRNGKey(seed)
    keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
        jnp.asarray(rids, jnp.int32))
    d = keys.shape[0]
    return {
        "key": keys.astype(jnp.uint32),
        "temperature": jnp.reshape(jnp.asarray(temperature, jnp.float32),
                                   (d,)),
        "top_k": jnp.reshape(jnp.asarray(top_k, jnp.int32), (d,)),
        "top_p": jnp.reshape(jnp.asarray(top_p, jnp.float32), (d,)),
        "eos_id": jnp.reshape(jnp.asarray(eos_id, jnp.int32), (d,)),
        "remaining": jnp.reshape(jnp.asarray(budget, jnp.int32), (d,)),
        "done": jnp.zeros((d,), bool),
    }


def slice_row(state: SamplerState, idx) -> SamplerState:
    """One-row slice of slot/row ``idx`` — the swap-out inverse of the
    ``admit_row`` -> scatter path.  The PRNG key, remaining budget and
    done flag leave the device mid-stream exactly as they are, so
    re-admitting the row through the slot scatter resumes the draw
    sequence at the position the request was preempted at (the key is a
    pure function of (seed, rid, tokens emitted) — never of wall time or
    slot placement — which is what makes swap/resume bitwise-safe)."""
    return {k: jax.lax.dynamic_slice_in_dim(v, idx, 1, axis=0)
            for k, v in state.items()}


def freeze_slot(state: SamplerState, slot) -> SamplerState:
    """Mark ``slot`` done after its request's state was gathered off the
    device: ``done`` is sticky in ``sample`` (frozen slots neither
    decrement budgets nor match EOS, and an all-greedy tick skips the
    stochastic pipeline), so a vacated slot is inert until the next
    admit scatters a new row over it."""
    return {**state, "done": state["done"].at[slot].set(True)}


# ------------------------------------------------------------- filtering

def _filter_row(logits, temperature, top_k, top_p):
    """One row of the filtering pipeline; returns scaled log-probs with
    excluded tokens at -inf.  Tokens tied with the top-k/top-p cutoff
    value are kept (same rule as the NumPy reference).  Both cutoffs are
    derived from a single full-vocab sort: top-k masks exactly the tail
    of the descending order, and softmax is monotone, so the nucleus
    boundary maps back to a threshold in scaled-log-prob space."""
    v = logits.shape[-1]
    logp = jax.nn.log_softmax(logits)
    scaled = logp / jnp.maximum(temperature, _MIN_TEMP)
    desc = jnp.sort(scaled)[::-1]
    # top-k: keep the k largest (log_softmax is monotonic, so ranking by
    # `scaled` equals ranking by raw logits)
    kth = desc[jnp.clip(top_k - 1, 0, v - 1)]
    desc = jnp.where((top_k > 0) & (desc < kth), _NEG_INF, desc)
    keep = (top_k <= 0) | (scaled >= kth)
    # top-p (nucleus) on the renormalized post-top-k distribution: keep
    # the smallest prefix of descending probs whose mass reaches top_p
    p_desc = jax.nn.softmax(desc)
    exclusive = jnp.cumsum(p_desc) - p_desc
    cutoff = jnp.min(jnp.where(exclusive < top_p, desc, jnp.inf))
    keep &= (top_p >= 1.0) | (scaled >= cutoff)
    return jnp.where(keep, scaled, _NEG_INF)


def filter_logits(logits, temperature, top_k, top_p):
    """Batched filtering: (S, V) logits + per-slot parameter arrays ->
    (S, V) scaled log-probs, excluded tokens at -inf."""
    return jax.vmap(_filter_row)(logits.astype(jnp.float32),
                                 temperature, top_k, top_p)


# -------------------------------------------------------------- sampling

def sample(state: SamplerState, logits):
    """One on-device sampling step over all slots + done-flag advance.

    logits: (S, V).  Returns (tokens (S,) int32, new state).  Greedy
    slots (temperature <= 0) take argmax of the raw logits; stochastic
    slots draw via Gumbel-max over the filtered log-probs.  ``remaining``
    only decrements for slots that were live this step, and ``done`` is
    sticky, so finished slots are frozen until re-admitted."""
    logits = logits.astype(jnp.float32)
    split = jax.vmap(jax.random.split)(state["key"])      # (S, 2, 2)
    new_key, sub = split[:, 0], split[:, 1]
    greedy = jnp.argmax(logits, axis=-1)

    def _stochastic():
        filtered = filter_logits(logits, state["temperature"],
                                 state["top_k"], state["top_p"])
        gumbel = jax.vmap(lambda k, shape=logits.shape[-1:]:
                          jax.random.gumbel(k, shape))(sub)
        drawn = jnp.argmax(filtered + gumbel, axis=-1)
        return jnp.where(state["temperature"] > 0.0, drawn, greedy)

    # ticks with no live stochastic slot skip the filter/sort/draw
    # pipeline entirely (done/free slots keep stale parameters); the key
    # split above is unconditional, so each slot's stream position stays
    # a function of its step count alone
    tok = jax.lax.cond(
        jnp.any((state["temperature"] > 0.0) & ~state["done"]),
        _stochastic, lambda: greedy)
    tok = tok.astype(jnp.int32)

    active = ~state["done"]
    remaining = state["remaining"] - active.astype(jnp.int32)
    hit_eos = (state["eos_id"] >= 0) & (tok == state["eos_id"])
    done = state["done"] | (active & (hit_eos | (remaining <= 0)))
    return tok, {**state, "key": new_key.astype(jnp.uint32),
                 "remaining": remaining, "done": done}


def sample_where(state: SamplerState, logits, active):
    """``sample``, but only rows where ``active`` advance their state.

    The speculative verify scan needs this: a slot that rejected a draft
    at position j stops emitting for the rest of the tick, and its
    sampler row must stop advancing at exactly that point — the key
    splits once per *emitted* token, never per verified position, so the
    draw stream stays the pure function of (seed, rid, tokens emitted)
    that non-speculative decode produces.  Rows are computed by the
    unmodified ``sample`` (identical arithmetic per row: the stochastic
    pipeline is a per-row vmap and the greedy fast path returns argmax
    either way), then masked back to the old state where inactive.

    Returns (tokens (S,) int32, new state); inactive rows' tokens are
    whatever ``sample`` drew from their stale parameters — callers mask
    them (the verify scan re-emits the slot's last token instead)."""
    tok, advanced = sample(state, logits)
    active = jnp.asarray(active)

    def _sel(new, old):
        mask = active.reshape(active.shape + (1,) * (new.ndim - 1))
        return jnp.where(mask, new, old)

    return tok, {k: _sel(advanced[k], state[k]) for k in state}


# -------------------------------------------- NumPy mirror (host + tests)

def filter_logits_np(logits: np.ndarray, temperature: float, top_k: int,
                     top_p: float) -> np.ndarray:
    """Reference pipeline for one (V,) row — identical cutoff rules to
    ``_filter_row`` (ties with the cutoff value are kept)."""
    logits = np.asarray(logits, np.float64)
    logp = logits - np.logaddexp.reduce(logits)           # log-softmax guard
    scaled = logp / max(temperature, _MIN_TEMP)
    if top_k > 0:
        kth = np.sort(scaled)[::-1][min(top_k, logits.size) - 1]
        scaled = np.where(scaled < kth, _NEG_INF, scaled)
    if top_p < 1.0:
        probs = np.exp(scaled - np.logaddexp.reduce(
            scaled[np.isfinite(scaled)]))
        desc = np.sort(probs)[::-1]
        exclusive = np.cumsum(desc) - desc
        cutoff = np.min(desc[exclusive < top_p])
        scaled = np.where(probs < cutoff, _NEG_INF, scaled)
    return scaled


def sample_np(rng: np.random.Generator, logits: np.ndarray, *,
              temperature: float, top_k: int = 0,
              top_p: float = 1.0) -> int:
    """Host-side draw matching the device pipeline's distribution (the
    test mirror; the serving admit path draws on device via the fused
    ``lm.prefill_sample`` head since the scheduler/executor split)."""
    if temperature <= 0.0:
        return int(np.argmax(logits))
    scaled = filter_logits_np(logits, temperature, top_k, top_p)
    keep = np.isfinite(scaled)
    p = np.zeros_like(scaled)
    p[keep] = np.exp(scaled[keep] - np.logaddexp.reduce(scaled[keep]))
    return int(rng.choice(p.size, p=p / p.sum()))
