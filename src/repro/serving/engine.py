"""Continuous-batching decode engine with persistent per-slot recurrent state.

This is the serving-side embodiment of the paper: every layer's recurrent
state (GDN S-matrices / SSD states / RG-LRU vectors) and KV caches live in
*donated* device buffers with a slot axis — XLA updates them in place every
tick, so state never leaves HBM and is touched exactly once per token by the
fused decode step (the TPU analogue of the FPGA's BRAM-resident state).

The decode hot loop is device-resident end to end: sampling (greedy /
temperature / top-k / top-p, per-slot parameters carried as arrays) and the
EOS / token-budget finished flags run on device next to the state, and each
engine tick fuses ``decode_block`` decode+sample steps into one ``lax.scan``
(``lm.decode_steps``).  The host therefore syncs once per ``decode_block``
tokens instead of once per token — the per-token logits round-trip was the
serving-layer version of the HBM round-trip the paper eliminates.

The slot buffers are sized and budgeted from the model's declarative
``cache_specs`` (one ``ArraySpec`` per cache leaf, exported by each
registered ``SequenceMixer``), so the engine is mixer-agnostic: a newly
registered kind serves without any engine change.  Admit scatters a
prefilled single-sequence cache into its slot with one jitted, donated
``dynamic_update_slice`` over the whole pytree, and writes the request's
sampling parameters into the sampler slot arrays alongside.

Scheduler: admit-on-free-slot continuous batching —
  1. each engine tick admits queued requests into free slots (per-request
     prefill, then the caches are scattered into the batched slot buffers);
     a request finished by its admit-time token (EOS, or max_new_tokens=1)
     completes immediately and never occupies a slot;
  2. one batched ``decode_block``-step scan advances *all* active slots,
     masking slots that finish mid-block;
  3. finished slots (EOS or max_new_tokens) are freed at the tick boundary.

Per-request wall-clock metrics (TTFT, latency, throughput) are stamped by
``submit``/admit/tick; ``DecodeEngine.metrics()`` aggregates them plus the
decode-only µs/token that ``benchmarks/bench_serving.py`` sweeps over
``decode_block``.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serving import sampling


@dataclass
class Request:
    rid: int
    prompt: Optional[np.ndarray] = None         # (T,) int32 token ids
    prompt_embeds: Optional[np.ndarray] = None  # (T, d_model) — stub
                                                # frontends (vlm/audio)
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 => disabled
    top_p: float = 1.0                  # 1.0 => disabled
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False
    # wall-clock stamps (perf_counter seconds), set by the engine
    t_submit: Optional[float] = None
    t_first: Optional[float] = None     # first token emitted (at admit)
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.latency_s
        if not lat:
            return None
        return len(self.output) / lat


def _scatter_fn(full, one, slot):
    """Write a single-sequence cache pytree into batch position `slot`.
    Leaves are (repeats, slots, ...) vs (repeats, 1, ...); `slot` is traced
    so the whole-pytree scatter compiles once and runs in place (donated)."""
    return jax.tree.map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=1),
        full, one)


class DecodeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 256, seed: int = 0, decode_block: int = 1):
        if decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got {decode_block}")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.seed = seed
        self.decode_block = decode_block
        # spec-driven slot buffers: shapes, dtypes and byte budgets all come
        # from the mixers' declarative cache specs
        self.spec = lm.cache_specs(cfg, max_slots, max_len)
        self.caches = self.spec.zeros()
        slot_spec = lm.cache_specs(cfg, 1, max_len)
        self.state_bytes_per_slot = slot_spec.state_bytes
        self.window_bytes_per_slot = slot_spec.window_bytes
        self.cache_bytes = self.spec.nbytes
        self.free: List[int] = list(range(max_slots))
        self.active: Dict[int, Request] = {}
        self.queue: Deque[Request] = deque()
        self._all: List[Request] = []
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        # per-slot sampler state lives in the slot buffers (donated each
        # tick with the caches); free slots are done=True
        self.sampler = sampling.init_state(max_slots)
        self._decode = jax.jit(
            lambda p, t, c, s: lm.decode_steps(
                p, cfg, t, c, decode_block,
                sampler=s, sample_fn=sampling.sample),
            donate_argnums=(2, 3))
        self._prefill = jax.jit(
            lambda p, t, c: lm.prefill(p, cfg, c, tokens=t))
        self._prefill_embeds = jax.jit(
            lambda p, e, c: lm.prefill(p, cfg, c, embeds=e))
        self._scatter = jax.jit(_scatter_fn, donate_argnums=(0,))
        self.ticks = 0
        self.decode_s = 0.0         # wall time inside decode ticks (+ sync)
        self.decoded_tokens = 0     # tokens emitted by ticks (not admit)
        self._metrics_from = 0      # _all watermark set by reset_metrics

    # ------------------------------------------------------------- admit
    def submit(self, req: Request):
        # reject out-of-range sampling params up front: past this point the
        # host mirror and the device pipeline must behave identically
        if not 0.0 < req.top_p <= 1.0:
            raise ValueError(f"req {req.rid}: top_p must be in (0, 1], "
                             f"got {req.top_p}")
        if req.top_k < 0:
            raise ValueError(f"req {req.rid}: top_k must be >= 0, "
                             f"got {req.top_k}")
        if req.temperature <= 0.0 and (req.top_k > 0 or req.top_p < 1.0):
            raise ValueError(f"req {req.rid}: top_k/top_p have no effect "
                             f"at temperature<=0 (greedy); set "
                             f"temperature > 0")
        if req.max_new_tokens < 1:
            raise ValueError(f"req {req.rid}: max_new_tokens must be >= 1 "
                             f"(admit always emits the first token), got "
                             f"{req.max_new_tokens}")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self._all.append(req)

    def _finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.popleft()
            one = lm.init_caches(self.cfg, 1, self.max_len)
            if req.prompt_embeds is not None:
                logits, one = self._prefill_embeds(
                    self.params,
                    jnp.asarray(req.prompt_embeds,
                                jnp.dtype(self.cfg.act_dtype))[None],
                    one)
            else:
                logits, one = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None, :], one)
            # admit-time token: host draw through the NumPy mirror of the
            # device pipeline, from a per-request stream so the sequence is
            # independent of slot placement and decode_block
            rng = np.random.default_rng((self.seed, req.rid))
            tok = sampling.sample_np(rng, np.asarray(logits)[0],
                                     temperature=req.temperature,
                                     top_k=req.top_k, top_p=req.top_p)
            req.output.append(int(tok))
            req.t_first = time.perf_counter()
            if self._finished(req, tok):
                # finished at admit (EOS or max_new_tokens=1): complete now,
                # never occupy a slot or decode an extra token
                req.done = True
                req.t_done = req.t_first
                continue
            slot = self.free.pop(0)
            self.caches = self._scatter(self.caches, one,
                                        jnp.int32(slot))
            self.tokens = self.tokens.at[slot].set(int(tok))
            self.sampler = sampling.admit_slot(
                self.sampler, slot, seed=self.seed, rid=req.rid,
                temperature=req.temperature, top_k=req.top_k,
                top_p=req.top_p, eos_id=req.eos_id,
                budget=req.max_new_tokens - len(req.output))
            self.active[slot] = req

    # ------------------------------------------------------------- tick
    def step(self):
        """One engine tick: admit, then one fused ``decode_block``-token
        decode+sample scan, then emit and free — a single host sync."""
        self._admit()
        if not self.active:
            return
        t0 = time.perf_counter()
        toks, valid, self.tokens, self.caches, self.sampler = self._decode(
            self.params, self.tokens, self.caches, self.sampler)
        toks = np.asarray(toks)          # (k, S) — the one host sync
        valid = np.asarray(valid)        # (k, S) live-going-into-step flags
        now = time.perf_counter()
        self.decode_s += now - t0
        self.ticks += 1
        for slot, req in list(self.active.items()):
            for j in range(toks.shape[0]):
                if not valid[j, slot]:
                    break
                tok = int(toks[j, slot])
                req.output.append(tok)
                self.decoded_tokens += 1
                if self._finished(req, tok):
                    req.done = True
                    req.t_done = now
                    del self.active[slot]
                    self.free.append(slot)
                    break

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.step()
        return [r for r in self._all if r.done]

    # ----------------------------------------------------------- metrics
    def reset_metrics(self):
        """Zero the aggregate counters (benchmarks call this after a
        warm-up pass so compile time stays out of the measurement)."""
        self.ticks = 0
        self.decode_s = 0.0
        self.decoded_tokens = 0
        self._metrics_from = len(self._all)

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over requests completed since the
        last ``reset_metrics`` (all requests by default)."""
        done = [r for r in self._all[self._metrics_from:] if r.done]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        return {
            "requests": len(done),
            "tokens": sum(len(r.output) for r in done),
            "ticks": self.ticks,
            "decode_block": self.decode_block,
            "decoded_tokens": self.decoded_tokens,
            "decode_s": self.decode_s,
            "decode_us_per_token":
                self.decode_s / max(1, self.decoded_tokens) * 1e6,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "mean_tokens_per_s": float(np.mean(tps)) if tps else 0.0,
        }
