"""Continuous-batching decode engine — thin façade over the
scheduler/executor split.

The engine used to be one module; it is now two layers (see
``docs/serving.md``):

  * ``repro.serving.scheduler.Scheduler`` — host side: queue, slot
    assignment, request lifecycle, overlapped chunked-prefill staging,
    budget-aware tick policy, metrics.
  * ``repro.serving.executor.DeviceExecutor`` — device side: the donated
    slot/staging buffers and every jitted program (fused decode+sample
    scan, chunked prefill with the fused on-device admit sample, slot
    scatter).

``DecodeEngine`` is the backwards-compatible entry point: the PR-2 API
(``submit`` / ``step`` / ``run_until_done`` / ``metrics``) is unchanged,
with new keyword knobs — ``overlap`` (chunked prefill staged while
resident slots decode; default on), ``prefill_chunk`` (chunk size) and
``budget_ticks`` (budget-aware tick length; default on).  ``overlap`` and
``budget_ticks`` move timing only: they run the same programs over the
same chunk plan, so token streams are bitwise identical across those
settings.  ``prefill_chunk`` changes the plan and hence float reduction
order — greedy streams are pinned identical by the test suite, but
temperature>0 draws may differ across chunk sizes.
"""
from __future__ import annotations

from repro.serving.scheduler import Request, Scheduler


class DecodeEngine(Scheduler):
    """Backwards-compatible façade over ``Scheduler`` + ``DeviceExecutor``."""


__all__ = ["DecodeEngine", "Request"]
