"""Continuous-batching decode engine with persistent per-slot recurrent state.

This is the serving-side embodiment of the paper: every layer's recurrent
state (GDN S-matrices / SSD states / RG-LRU vectors) and KV caches live in
*donated* device buffers with a slot axis — XLA updates them in place every
tick, so state never leaves HBM and is touched exactly once per token by the
fused decode step (the TPU analogue of the FPGA's BRAM-resident state).

The slot buffers are sized and budgeted from the model's declarative
``cache_specs`` (one ``ArraySpec`` per cache leaf, exported by each
registered ``SequenceMixer``), so the engine is mixer-agnostic: a newly
registered kind serves without any engine change.  Admit scatters a
prefilled single-sequence cache into its slot with one jitted, donated
``dynamic_update_slice`` over the whole pytree — the buffers are updated
on-device in place instead of rebuilt leaf-by-leaf on the host.

Scheduler: admit-on-free-slot continuous batching —
  1. each engine tick admits queued requests into free slots (per-request
     prefill, then the caches are scattered into the batched slot buffers);
     a request finished by its admit-time token (EOS, or max_new_tokens=1)
     completes immediately and never occupies a slot;
  2. one batched decode step advances *all* active slots;
  3. finished slots (EOS or max_new_tokens) are freed immediately.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: Optional[np.ndarray] = None         # (T,) int32 token ids
    prompt_embeds: Optional[np.ndarray] = None  # (T, d_model) — stub
                                                # frontends (vlm/audio)
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False


def _scatter_fn(full, one, slot):
    """Write a single-sequence cache pytree into batch position `slot`.
    Leaves are (repeats, slots, ...) vs (repeats, 1, ...); `slot` is traced
    so the whole-pytree scatter compiles once and runs in place (donated)."""
    return jax.tree.map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=1),
        full, one)


class DecodeEngine:
    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        # spec-driven slot buffers: shapes, dtypes and byte budgets all come
        # from the mixers' declarative cache specs
        self.spec = lm.cache_specs(cfg, max_slots, max_len)
        self.caches = self.spec.zeros()
        slot_spec = lm.cache_specs(cfg, 1, max_len)
        self.state_bytes_per_slot = slot_spec.state_bytes
        self.window_bytes_per_slot = slot_spec.window_bytes
        self.cache_bytes = self.spec.nbytes
        self.free: List[int] = list(range(max_slots))
        self.active: Dict[int, Request] = {}
        self.queue: List[Request] = []
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.rng = np.random.default_rng(seed)
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c),
            donate_argnums=(2,))
        self._prefill = jax.jit(
            lambda p, t, c: lm.prefill(p, cfg, c, tokens=t))
        self._prefill_embeds = jax.jit(
            lambda p, e, c: lm.prefill(p, cfg, c, embeds=e))
        self._scatter = jax.jit(_scatter_fn, donate_argnums=(0,))
        self.ticks = 0

    # ------------------------------------------------------------- admit
    def submit(self, req: Request):
        self.queue.append(req)
        self._all: List[Request] = getattr(self, "_all", [])
        self._all.append(req)

    def _finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    def _admit(self):
        while self.queue and self.free:
            req = self.queue.pop(0)
            one = lm.init_caches(self.cfg, 1, self.max_len)
            if req.prompt_embeds is not None:
                logits, one = self._prefill_embeds(
                    self.params,
                    jnp.asarray(req.prompt_embeds,
                                jnp.dtype(self.cfg.act_dtype))[None],
                    one)
            else:
                logits, one = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None, :], one)
            tok = self._sample(np.asarray(logits)[0], req)
            req.output.append(int(tok))
            if self._finished(req, tok):
                # finished at admit (EOS or max_new_tokens=1): complete now,
                # never occupy a slot or decode an extra token
                req.done = True
                continue
            slot = self.free.pop(0)
            self.caches = self._scatter(self.caches, one,
                                        jnp.int32(slot))
            self.tokens = self.tokens.at[slot].set(int(tok))
            self.active[slot] = req

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0.0:
            return int(np.argmax(logits))
        p = logits / req.temperature
        p = np.exp(p - p.max())
        p = p / p.sum()
        return int(self.rng.choice(len(p), p=p))

    # ------------------------------------------------------------- tick
    def step(self):
        """One engine tick: admit, batched decode, emit, free."""
        self._admit()
        if not self.active:
            return
        logits, self.caches = self._decode(self.params, self.tokens,
                                           self.caches)
        logits = np.asarray(logits)
        self.ticks += 1
        new_tokens = np.array(self.tokens)   # mutable copy
        for slot, req in list(self.active.items()):
            tok = self._sample(logits[slot], req)
            req.output.append(tok)
            new_tokens[slot] = tok
            if self._finished(req, tok):
                req.done = True
                del self.active[slot]
                self.free.append(slot)
        self.tokens = jnp.asarray(new_tokens)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.step()
        return [r for r in getattr(self, "_all", []) if r.done]
