"""Continuous-batching decode engine — thin façade over the
scheduler/executor split.

The engine used to be one module; it is now two layers (see
``docs/serving.md``):

  * ``repro.serving.scheduler.Scheduler`` — host side: queue, slot
    assignment, request lifecycle, overlapped chunked-prefill staging
    (a ring of ``staging_depth`` buffers), budget-aware tick policy,
    metrics.
  * ``repro.serving.executor.DeviceExecutor`` — device side: the donated
    slot/staging buffers and every jitted program (fused decode+sample
    scan, chunked prefill with the fused on-device admit sample, slot
    scatter).  With ``mesh=`` set, every buffer is allocated with a
    ``NamedSharding`` (slot axis on "data", state heads / KV context on
    "model") and every program is compiled with explicit in/out
    shardings — one SPMD program per tick over the whole mesh.

Above the engine, ``repro.serving.router.Router`` fronts one-or-more
per-mesh engines (placement, swap-aware rebalance/drain, aggregated
metrics).  Engines may live in **other processes**: an
``repro.serving.rpc.EngineProxy`` speaks the same surface over a framed
pipe protocol to an ``EngineWorker`` subprocess hosting its own
``Scheduler``, and engines carry a ``role`` (``prefill``/``decode``/
``both``) for disaggregated serving — prefill engines pause every
request at the admit boundary and the router ships the swapped image to
a decode engine (see ``docs/serving.md``).

**Slot oversubscription** (state paging): the engine serves more live
sessions than device slots.  ``pause(rid)`` gathers a request's whole
fixed-size device residency (recurrent state + rolling KV window +
sampler row + last token — all shapes from ``cache_spec``) into a
host-side ``SwappedState`` and frees the slot; ``resume(rid)`` queues it
for a slot grant and swap-in re-admits it through the existing
slot-scatter program, bitwise-identically; ``preempt()`` evicts the
lowest-priority active request with automatic resume.  The lifecycle
gains SWAPPED and RESUMING states (``Request.state``), ``swap_policy``
("manual"/"idle"/"pressure"/"auto" with ``idle_swap_ms``) automates
eviction, and ``max_live_requests`` caps total admission including
swapped sessions.  See ``docs/serving.md``.

**Async paging** (``async_paging=True``): swap transfers overlap the
decode tick instead of serializing with it.  A swap-out dispatches the
gather program into a ring of ``gather_ring`` device-side buffers, frees
the slot immediately and lets the D2H copy drain in the background
(``copy_to_host_async``); the scheduler harvests completed drains at
tick boundaries — a pending-swap ledger guarantees a draining buffer is
never reused pre-harvest.  A predictable resume grant prestages its H2D
put one tick ahead so the grant-boundary scatter consumes an
already-device-resident image; a cancelled resume drops the prefetch.
Gather outputs snapshot values at dispatch, so streams stay bitwise
identical to the synchronous fallback (``async_paging=False``, the
default).  ``metrics()`` splits ``swap_s`` into ``swap_dispatch_s`` /
``swap_stall_s`` plus gather/put/scatter and overlap-ratio breakdowns.
Beyond a ``host_swap_bytes`` watermark of in-memory swapped images, the
coldest dormant ``SwappedState`` spills to a wire-encoded image under
``swap_spool_dir`` and reloads transparently on resume (spill-to-disk
tier for truly cold sessions).  See ``docs/serving.md``.

**Speculative decode** (draft–verify with recurrent-state rollback):
``speculative=True`` runs the whole draft–verify loop inside the
device-resident tick.  A draft model (``draft_cfg``/``draft_params``;
default: the target itself, "self-draft") holds its own per-slot caches
and proposes ``k_draft`` tokens per slot; one fused verify program
teacher-forces the target over the proposals, samples each position with
the SAME per-slot key sequence non-speculative decode would use
(greedy and stochastic streams are therefore bitwise identical to
``speculative=False``), and rolls every slot's recurrent state back to
its last accepted position through a per-slot checkpoint buffer declared
in ``cache_spec``-style specs (``SequenceMixer.checkpoint_spec``).
Drafts for the next tick are dispatched before the host touches the
current verify's tokens, so each emitted run of up to ``k_draft + 1``
tokens still costs one host sync.  ``pause``/``preempt`` during a
pending draft defer to the verify boundary.  See ``docs/serving.md``.

``DecodeEngine`` is the backwards-compatible entry point: the PR-2 API
(``submit`` / ``step`` / ``run_until_done`` / ``metrics``) is unchanged,
with keyword knobs — ``overlap`` (chunked prefill staged while resident
slots decode; default on), ``prefill_chunk`` (chunk size),
``plan_mode`` ("masked" default: one scan shape + one fixed-size
``valid_len``-masked tail per prompt, ≤ 2 prefill program shapes;
"pow2": the power-of-two tail baseline — token streams are identical
across modes, only the compile cache moves), ``budget_ticks``
(budget-aware tick length; default on), ``mesh`` (a
``("data", "model")`` device mesh; default single-device),
``staging_depth`` (ahead-of-slot prefills outstanding under saturation;
default 2), ``prefill_batching`` (fuse ALL staged prompts into one
batched fixed-shape prefill program per dispatch, with per-row
``valid_lens`` masking and a multi-row slot scatter — dispatches per
tick are O(1) in queue depth; default auto: on whenever every mixer
kind supports per-row masks and the FFN is not MoE, off otherwise) and
``prefill_budget`` (the batched packer's per-tick prefill token budget
under saturation; default ``staging_depth`` full scans + admits).
``overlap``, ``budget_ticks``, ``staging_depth``, ``prefill_batching``,
``prefill_budget`` and the *data axis* of the mesh move
timing/placement only: they run the same per-row chunk math over the
same C-quantized chunk decompositions, so token streams are bitwise
identical across those settings.  ``prefill_chunk`` changes the plan and
hence float reduction order, and the mesh's *model* axis splits head /
context reductions across devices (psum partial ordering) — greedy
streams are pinned identical by the test suite for chunk sizes, but
model-sharded engines may legitimately diverge in low-probability tokens
exactly as any tensor-parallel serving stack does (see
``docs/serving.md``).
"""
from __future__ import annotations

from repro.serving.router import Router
from repro.serving.rpc import EngineProxy, WorkerDied
from repro.serving.scheduler import Request, Scheduler


class DecodeEngine(Scheduler):
    """Backwards-compatible façade over ``Scheduler`` + ``DeviceExecutor``."""


__all__ = ["DecodeEngine", "EngineProxy", "Request", "Router",
           "WorkerDied"]
