"""Host scheduler: request lifecycle, slot assignment and tick policy.

This is the host half of the scheduler/executor split (the device half —
slot/staging buffers and every jitted program — is
``repro.serving.executor.DeviceExecutor``).  The scheduler never touches a
device buffer directly; it decides *what* to dispatch and *when*:

  1. **submit** validates a request (sampling parameters, token budget,
     prompt length vs ``max_len`` — an over-long prompt would wrap the
     rolling window caches mid-prompt and silently corrupt them) and
     appends it to a FIFO queue.
  2. **staging admit** (overlapped, the default): queued requests prefill
     *chunk by chunk* into the executor's staging buffers at tick
     boundaries.  While free slots exist this is work-conserving (same
     admits as the serialized baseline); once every slot is busy, the
     head-of-queue request still prefills ahead of any free slot, emits
     its first token (the final chunk fuses the draw on device — no host
     ``sample_np``), and is held staged-ready until a slot frees.  TTFT is
     stamped when that token is device-confirmed (synced to the host),
     not when the dispatch is queued.  With ``overlap=False`` the same
     programs run back-to-back behind a free slot (the serialized
     baseline — token streams are bitwise identical, only timing moves).
  3. **tick** (`step`): one fused decode+sample scan over all slots.  The
     tick length is **budget-aware**: the smallest power-of-two bucket
     (capped at ``decode_block``) covering the largest remaining per-slot
     budget, so the tail ticks of a batch of short budgets stop burning
     masked steps — bucketing bounds the compile cache.
  4. finished slots (device EOS/budget flags) are freed at tick boundaries.

Wall-clock metrics (TTFT, latency, throughput) are stamped per request;
``metrics()`` aggregates them plus the decode-only µs/token that
``benchmarks/bench_serving.py`` sweeps.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.executor import DeviceExecutor


@dataclass
class Request:
    rid: int
    prompt: Optional[np.ndarray] = None         # (T,) int32 token ids
    prompt_embeds: Optional[np.ndarray] = None  # (T, d_model) — stub
                                                # frontends (vlm/audio)
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 => disabled
    top_p: float = 1.0                  # 1.0 => disabled
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False
    # wall-clock stamps (perf_counter seconds), set by the engine
    t_submit: Optional[float] = None
    t_first: Optional[float] = None     # first token device-confirmed
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.latency_s
        if not lat:
            return None
        return len(self.output) / lat

    @property
    def prompt_len(self) -> Optional[int]:
        if self.prompt is not None:
            return int(np.asarray(self.prompt).shape[-1])
        if self.prompt_embeds is not None:
            return int(np.asarray(self.prompt_embeds).shape[0])
        return None

    @property
    def _inputs(self):
        return self.prompt if self.prompt is not None else self.prompt_embeds


class Scheduler:
    """Continuous-batching decode scheduler over a ``DeviceExecutor``."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 256, seed: int = 0, decode_block: int = 1,
                 overlap: bool = True, prefill_chunk: int = 16,
                 budget_ticks: bool = True):
        if decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got {decode_block}")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.seed = seed
        self.decode_block = decode_block
        self.overlap = overlap
        self.budget_ticks = budget_ticks
        self.executor = DeviceExecutor(
            cfg, params, max_slots=max_slots, max_len=max_len,
            decode_block=decode_block, prefill_chunk=prefill_chunk)
        self.free: Deque[int] = deque(range(max_slots))
        self.active: Dict[int, Request] = {}
        self.queue: Deque[Request] = deque()
        self._all: List[Request] = []
        # staging state machine (one request prefilling ahead of its slot)
        self._staging: Optional[Request] = None
        self._plan = []
        self._plan_pos = 0
        self._prompt_pos = 0
        self._staged_ready = False
        self.ticks = 0
        self.decode_s = 0.0         # wall time inside decode ticks (+ sync)
        self.decoded_tokens = 0     # tokens emitted by ticks (not admit)
        self.stage_dispatches = 0   # prefill-chunk programs dispatched
        self._metrics_from = 0      # _all watermark set by reset_metrics

    # ---------------------------------------------------- compat surface
    @property
    def spec(self):
        return self.executor.spec

    @property
    def prefill_chunk(self) -> int:
        return self.executor.prefill_chunk

    @property
    def state_bytes_per_slot(self) -> int:
        return self.executor.state_bytes_per_slot

    @property
    def window_bytes_per_slot(self) -> int:
        return self.executor.window_bytes_per_slot

    @property
    def cache_bytes(self) -> int:
        return self.executor.cache_bytes

    @property
    def caches(self):
        return self.executor.caches

    @property
    def tokens(self):
        return self.executor.tokens

    @property
    def sampler(self):
        return self.executor.sampler

    # ------------------------------------------------------------ submit
    def submit(self, req: Request):
        # reject out-of-range sampling params up front: past this point the
        # host mirror and the device pipeline must behave identically
        if not 0.0 < req.top_p <= 1.0:
            raise ValueError(f"req {req.rid}: top_p must be in (0, 1], "
                             f"got {req.top_p}")
        if req.top_k < 0:
            raise ValueError(f"req {req.rid}: top_k must be >= 0, "
                             f"got {req.top_k}")
        if req.temperature <= 0.0 and (req.top_k > 0 or req.top_p < 1.0):
            raise ValueError(f"req {req.rid}: top_k/top_p have no effect "
                             f"at temperature<=0 (greedy); set "
                             f"temperature > 0")
        if req.max_new_tokens < 1:
            raise ValueError(f"req {req.rid}: max_new_tokens must be >= 1 "
                             f"(admit always emits the first token), got "
                             f"{req.max_new_tokens}")
        T = req.prompt_len
        if T is None:
            raise ValueError(f"req {req.rid}: needs a prompt or "
                             f"prompt_embeds")
        if T < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if T > self.max_len:
            raise ValueError(
                f"req {req.rid}: prompt length {T} exceeds max_len "
                f"{self.max_len} — the window caches would wrap "
                f"mid-prompt and silently corrupt the context")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self._all.append(req)

    def _finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # ----------------------------------------------------------- staging
    def _stage_start(self, req: Request):
        self._staging = req
        self._plan = self.executor.plan_prefill(req.prompt_len)
        self._plan_pos = 0
        self._prompt_pos = 0
        self._staged_ready = False
        self.executor.stage_begin(
            seed=self.seed, rid=req.rid, temperature=req.temperature,
            top_k=req.top_k, top_p=req.top_p, eos_id=req.eos_id,
            budget=req.max_new_tokens)

    def _stage_dispatch_one(self):
        kind, n = self._plan[self._plan_pos]
        inputs = self._staging._inputs
        size = n * self.executor.prefill_chunk if kind == "scan" else n
        chunk = inputs[self._prompt_pos:self._prompt_pos + size]
        if kind == "scan":
            self.executor.stage_chunk_scan(chunk)
        elif kind == "chunk":
            self.executor.stage_chunk(chunk)
        else:
            self.executor.stage_admit(chunk)
        self._prompt_pos += size
        self._plan_pos += 1
        self.stage_dispatches += 1

    def _stage_finish(self):
        """Plan complete: sync the fused first token (this is the
        device-confirmed admit — TTFT is stamped here, not when the
        dispatch was queued) and either complete the request (EOS /
        max_new_tokens=1, never occupying a slot) or hold it staged-ready
        until a slot frees."""
        req = self._staging
        tok = int(np.asarray(self.executor.staging_tok)[0])
        req.t_first = time.perf_counter()
        req.output.append(tok)
        if self._finished(req, tok):
            req.done = True
            req.t_done = req.t_first
            self._staging = None
            return
        self._staged_ready = True

    def _stage_scatter(self):
        slot = self.free.popleft()
        self.executor.scatter(slot)
        self.active[slot] = self._staging
        self._staging = None
        self._staged_ready = False

    def _admit(self):
        """Advance the admit pipeline at a tick boundary.

        Work-conserving: while free slots exist, queued requests prefill
        and scatter exactly as the serialized baseline does.  The overlap
        is purely additive — when every slot is busy, the head-of-queue
        request *still* streams its chunk plan into the staging buffer,
        **one chunk dispatch per tick** so the resident slots keep
        decoding between chunks, and emits its fused-sample first token at
        plan completion, held staged-ready until a slot frees (at most one
        such ahead-of-slot prefill can be outstanding, because the staged
        request owns the staging buffer until its scatter).  Overlapped
        TTFT is therefore never structurally worse than serialized, and
        strictly better whenever a request would have had to wait for a
        slot before prefilling."""
        while True:
            if self._staging is None:
                if not self.queue:
                    return
                if not self.free and not self.overlap:
                    return      # serialized admit waits for a slot up front
                self._stage_start(self.queue.popleft())
            if self._staged_ready:
                if not self.free:
                    return      # token already emitted; slot-bound
                self._stage_scatter()
                continue        # next queued request may start staging
            self._stage_dispatch_one()
            if self._plan_pos == len(self._plan):
                self._stage_finish()
            elif not self.free and self.active:
                return          # ahead-of-slot: yield so the resident
                                # slots decode between prefill chunks

    # -------------------------------------------------------------- tick
    def _tick_k(self) -> int:
        """Budget-aware tick length: smallest power-of-two bucket (capped
        at ``decode_block``) covering the largest remaining per-slot
        budget — the all-slots-finish-early tail stops burning masked
        scan steps, and bucketing bounds the program cache."""
        if not self.budget_ticks:
            return self.decode_block
        need = max(r.max_new_tokens - len(r.output)
                   for r in self.active.values())
        k = 1
        while k < need and k < self.decode_block:
            k <<= 1
        return min(k, self.decode_block)

    def step(self):
        """One engine tick: advance the admit pipeline (free slots fill as
        in the serialized baseline, plus at most one ahead-of-slot staged
        prefill when every slot is busy), then one fused decode+sample
        scan, then emit and free — a single host sync for the decode
        block."""
        self._admit()
        if not self.active:
            return
        k = self._tick_k()
        t0 = time.perf_counter()
        toks, valid = self.executor.decode(k)   # (k, S) — the one host sync
        now = time.perf_counter()
        self.decode_s += now - t0
        self.ticks += 1
        for slot, req in list(self.active.items()):
            for j in range(toks.shape[0]):
                if not valid[j, slot]:
                    break
                tok = int(toks[j, slot])
                req.output.append(tok)
                self.decoded_tokens += 1
                if self._finished(req, tok):
                    req.done = True
                    req.t_done = now
                    del self.active[slot]
                    self.free.append(slot)
                    break

    def run_until_done(self, max_ticks: int = 10_000, *,
                       strict: bool = True) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active and self._staging is None:
                break
            self.step()
        if self.queue or self.active or self._staging is not None:
            msg = (f"run_until_done: max_ticks={max_ticks} exhausted with "
                   f"{len(self.queue)} queued, {len(self.active)} active, "
                   f"{int(self._staging is not None)} staging request(s) "
                   f"unfinished — raise max_ticks or inspect the engine")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning)
        return [r for r in self._all if r.done]

    # ----------------------------------------------------------- metrics
    def reset_metrics(self):
        """Zero the aggregate counters (benchmarks call this after a
        warm-up pass so compile time stays out of the measurement)."""
        self.ticks = 0
        self.decode_s = 0.0
        self.decoded_tokens = 0
        self.stage_dispatches = 0
        self._metrics_from = len(self._all)

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over requests completed since the
        last ``reset_metrics`` (all requests by default)."""
        done = [r for r in self._all[self._metrics_from:] if r.done]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        return {
            "requests": len(done),
            "tokens": sum(len(r.output) for r in done),
            "ticks": self.ticks,
            "decode_block": self.decode_block,
            "decoded_tokens": self.decoded_tokens,
            "decode_s": self.decode_s,
            "decode_us_per_token":
                self.decode_s / max(1, self.decoded_tokens) * 1e6,
            "stage_dispatches": self.stage_dispatches,
            "overlap": int(self.overlap),
            "prefill_chunk": self.executor.prefill_chunk,
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "mean_tokens_per_s": float(np.mean(tps)) if tps else 0.0,
        }
