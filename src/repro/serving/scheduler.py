"""Host scheduler: request lifecycle, slot assignment and tick policy.

This is the host half of the scheduler/executor split (the device half —
slot/staging buffers and every jitted program — is
``repro.serving.executor.DeviceExecutor``).  The scheduler never touches a
device buffer directly; it decides *what* to dispatch and *when*:

  1. **submit** validates a request (sampling parameters, token budget,
     prompt length vs ``max_len`` — an over-long prompt would wrap the
     rolling window caches mid-prompt and silently corrupt them) and
     appends it to a FIFO queue.
  2. **staging admit** (overlapped, the default): queued requests prefill
     *chunk by chunk* into the executor's staging ring at tick
     boundaries.  While free slots exist this is work-conserving (same
     admits as the serialized baseline); once every slot is busy, up to
     ``staging_depth`` head-of-queue requests still prefill ahead of any
     free slot — one chunk dispatch per staged request per tick — emit
     their first tokens (the final chunk fuses the draw on device — no
     host ``sample_np``), and are held staged-ready until slots free
     (scattered in FIFO order).  TTFT is stamped when that token is
     device-confirmed (synced to the host), not when the dispatch is
     queued.  With ``overlap=False`` the same programs run back-to-back
     behind a free slot (the serialized baseline — token streams are
     bitwise identical, only timing moves).
  3. **tick** (`step`): one fused decode+sample scan over all slots.  The
     tick length is **budget-aware**: the smallest power-of-two bucket
     (capped at ``decode_block``) covering the largest remaining per-slot
     budget, so the tail ticks of a batch of short budgets stop burning
     masked steps — bucketing bounds the compile cache.
  4. finished slots (device EOS/budget flags) are freed at tick boundaries.

**State paging (slot oversubscription).**  The paper's core claim is
that a *fixed-size* persistent state is what makes linear-attention
decode accelerable; the serving analog of on-chip capacity is the slot
count.  Because every mixer's state is a constant-shape block described
by ``cache_spec``, an idle request's whole device residency (recurrent
state + rolling KV window + sampler row + last token) gathers into one
host-side ``SwappedState`` record — no block tables, no paged KV.
``pause(rid)`` swaps a request out wherever it is in the lifecycle
(SWAPPED), ``resume(rid)`` queues it for a slot grant (RESUMING),
``preempt()`` evicts the lowest-priority active request with automatic
resume, and ``swap_policy`` runs an idle-lease and/or priority-pressure
sweep each tick.  Swap-in re-admits through the EXISTING slot-scatter
program, and the sampler row round-trips the PRNG key mid-stream, so a
preempted-and-resumed stream is bitwise the uninterrupted one
(``tests/test_state_paging.py``).  Freed-slot grants alternate between
the resume queue and staged-ready fresh admits (both FIFO) so neither
class starves; an engine can thus hold arbitrarily more live sessions
than ``max_slots`` (capped by ``max_live_requests``).

With ``mesh`` set, the executor allocates every buffer with NamedShardings
(slot axis on "data", state heads / KV context on "model") and compiles
every program with explicit in/out shardings — the scheduler logic is
topology-blind; only the buffers underneath it are distributed.

Wall-clock metrics (TTFT, latency, throughput) are stamped per request;
``metrics()`` aggregates them plus the decode-only µs/token that
``benchmarks/bench_serving.py`` sweeps.
"""
from __future__ import annotations

import os
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.serving import wire
from repro.serving.executor import (DeviceExecutor, PendingSwap, PlanStep,
                                    SwappedState)


# request lifecycle states (the serving.md diagram): a request is QUEUED,
# then STAGING (chunked prefill into the ring), READY (first token drawn,
# waiting for a slot), ACTIVE (slot-resident, decoding) and DONE — plus
# the paging states: SWAPPED (device image gathered to host, or paused
# straight out of the queue) and RESUMING (in the resume queue, waiting
# for a granted slot to scatter back into)
QUEUED, STAGING, READY, ACTIVE = "queued", "staging", "ready", "active"
SWAPPED, RESUMING, DONE = "swapped", "resuming", "done"
# sub-phases of a swap record under async paging / spill (the request's
# lifecycle state stays SWAPPED or RESUMING — these describe where its
# *image* is): DRAINING = gather dispatched, D2H still in flight;
# HOSTED = image is host numpy; PREFETCHED = image prestaged back on
# device awaiting a predicted grant; SPILLED = image is a wire-encoded
# file in the spool dir
DRAINING, HOSTED = "draining", "hosted"
PREFETCHED, SPILLED = "prefetched", "spilled"


@dataclass
class Request:
    rid: int
    prompt: Optional[np.ndarray] = None         # (T,) int32 token ids
    prompt_embeds: Optional[np.ndarray] = None  # (T, d_model) — stub
                                                # frontends (vlm/audio)
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 => disabled
    top_p: float = 1.0                  # 1.0 => disabled
    eos_id: Optional[int] = None
    priority: int = 0                   # pressure eviction: a strictly
                                        # higher priority wins a slot
                                        # from a lower one
    output: List[int] = field(default_factory=list)
    done: bool = False
    state: str = "new"                  # lifecycle (QUEUED..DONE above)
    # wall-clock stamps (perf_counter seconds), set by the engine
    t_submit: Optional[float] = None
    t_first: Optional[float] = None     # first token device-confirmed
    t_done: Optional[float] = None
    swapped_s: float = 0.0              # total wall time swapped out
    _swapped_pre_first_s: float = 0.0   # swapped time before first token
    t_last_activity: Optional[float] = None  # lease stamp (idle policy):
                                        # set at submit/activation,
                                        # refreshed by Scheduler.touch
    _t_active: Optional[float] = None   # most recent slot activation

    @property
    def ttft_s(self) -> Optional[float]:
        """Submit -> first token, EXCLUDING time the request spent
        swapped out before it ever reached the device (a paused-then-
        resumed queued request isn't "waiting", its client left)."""
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit - self._swapped_pre_first_s

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def active_latency_s(self) -> Optional[float]:
        """Wall latency minus swapped-out time — the denominator for
        throughput: a request that sat paused for an hour did not decode
        slowly for an hour."""
        lat = self.latency_s
        if lat is None:
            return None
        return lat - self.swapped_s

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.active_latency_s
        if not lat:
            return None
        return len(self.output) / lat

    @property
    def prompt_len(self) -> Optional[int]:
        if self.prompt is not None:
            return int(np.asarray(self.prompt).shape[-1])
        if self.prompt_embeds is not None:
            return int(np.asarray(self.prompt_embeds).shape[0])
        return None

    @property
    def _inputs(self):
        return self.prompt if self.prompt is not None else self.prompt_embeds


@dataclass(eq=False)      # identity semantics: entries are removed by `is`
class _Staging:
    """One in-flight staged prefill: a request bound to an executor ring
    buffer (= a batched staging row), with its chunk-plan progress and
    staged-ready flag.  The per-prompt path walks ``plan``; the batched
    path tracks ``chunks_left`` full chunks + the fixed-size masked
    ``tail`` directly (its "plan" is whatever the per-tick packer
    allocates)."""
    req: Request
    plan: List[PlanStep]
    buf: int
    plan_pos: int = 0
    prompt_pos: int = 0
    ready: bool = False
    chunks_left: int = 0      # batched path: full C-chunks not yet staged
    tail: int = 0             # batched path: valid tokens in the admit chunk
    admitted: bool = False    # batched path: admit dispatched, token pending
    pause_pending: bool = False  # pause() hit mid-prefill: swap out at
                                 # the admit boundary instead of holding
                                 # the request staged-ready


@dataclass(eq=False)
class _Swapped:
    """One swapped-out request: its host-side device image (None when it
    was paused straight out of the queue — nothing was resident to
    gather) and the wall-clock stamp the swap started at (the gather
    *dispatch*, so parked-time exclusion spans dispatch → restore
    scatter regardless of when the drain is harvested).

    Under async paging the image moves through sub-phases: ``pending``
    holds the in-flight gather (DRAINING) until a harvest materializes
    ``state``; ``prefetch`` holds a device-resident restore triple
    (PREFETCHED) staged ahead of a predicted grant; ``spool`` points at
    an on-disk wire-encoded file (SPILLED) once the watermark pushed the image
    out of memory."""
    req: Request
    state: Optional[SwappedState]
    t_swap: float
    pending: Optional[PendingSwap] = None
    prefetch: Optional[tuple] = None
    spool: Optional[str] = None

    @property
    def phase(self) -> str:
        if self.pending is not None:
            return DRAINING
        if self.prefetch is not None:
            return PREFETCHED
        if self.spool is not None:
            return SPILLED
        return HOSTED


class Scheduler:
    """Continuous-batching decode scheduler over a ``DeviceExecutor``."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 256, seed: int = 0, decode_block: int = 1,
                 overlap: bool = True, prefill_chunk: int = 16,
                 budget_ticks: bool = True, mesh=None,
                 staging_depth: int = 2, plan_mode: str = "masked",
                 prefill_batching: Optional[bool] = None,
                 prefill_budget: Optional[int] = None,
                 swap_policy: str = "manual",
                 idle_swap_ms: Optional[float] = None,
                 max_live_requests: Optional[int] = None,
                 async_paging: bool = False, gather_ring: int = 2,
                 host_swap_bytes: Optional[int] = None,
                 swap_spool_dir: Optional[str] = None,
                 speculative: bool = False, draft_cfg=None,
                 draft_params=None, k_draft: int = 4,
                 adaptive_k: bool = False, role: str = "both"):
        if decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got {decode_block}")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(f"prefill_budget must be >= 1 token, got "
                             f"{prefill_budget}")
        if swap_policy not in ("manual", "idle", "pressure", "auto"):
            raise ValueError(f"swap_policy must be one of manual/idle/"
                             f"pressure/auto, got {swap_policy!r}")
        if swap_policy in ("idle", "auto") and idle_swap_ms is None:
            raise ValueError(f"swap_policy={swap_policy!r} sweeps idle "
                             f"leases — set idle_swap_ms")
        if idle_swap_ms is not None and idle_swap_ms < 0:
            raise ValueError(f"idle_swap_ms must be >= 0, got "
                             f"{idle_swap_ms}")
        if max_live_requests is not None and max_live_requests < 1:
            raise ValueError(f"max_live_requests must be >= 1, got "
                             f"{max_live_requests}")
        if host_swap_bytes is not None and host_swap_bytes < 0:
            raise ValueError(f"host_swap_bytes must be >= 0, got "
                             f"{host_swap_bytes}")
        if host_swap_bytes is not None and swap_spool_dir is None:
            raise ValueError("host_swap_bytes is a spill watermark — set "
                             "swap_spool_dir so cold images have "
                             "somewhere to go")
        if (draft_cfg is not None or draft_params is not None) \
                and not speculative:
            raise ValueError("draft_cfg/draft_params given without "
                             "speculative=True")
        if adaptive_k and not speculative:
            raise ValueError("adaptive_k tunes the speculative draft "
                             "length — set speculative=True")
        if role not in ("prefill", "decode", "both"):
            raise ValueError(f"role must be one of prefill/decode/both, "
                             f"got {role!r}")
        self.role = role
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.seed = seed
        self.decode_block = decode_block
        self.overlap = overlap
        self.budget_ticks = budget_ticks
        # speculative decode: default draft is the target itself
        # (self-draft — acceptance 1.0, the upper bound benchmarks use;
        # real deployments pass a trained smaller draft_cfg/draft_params)
        self.speculative = speculative
        self.k_draft = k_draft
        # acceptance-adaptive draft length: a windowed acceptance rate
        # shrinks/grows the effective k within [1, k_draft] — a bad
        # draft model collapses to verify-heavy k=1 ticks instead of
        # burning k rejected proposals per sync; streams are unaffected
        # (the shared-key verify emits the same tokens at any k)
        self.adaptive_k = bool(adaptive_k)
        self._k_eff = k_draft
        self._accept_window: Deque[tuple] = deque(maxlen=4)
        if speculative and draft_cfg is None:
            draft_cfg, draft_params = cfg, params
        self.executor = DeviceExecutor(
            cfg, params, max_slots=max_slots, max_len=max_len,
            decode_block=decode_block, prefill_chunk=prefill_chunk,
            mesh=mesh, staging_depth=staging_depth, plan_mode=plan_mode,
            prefill_batching=prefill_batching,
            draft_cfg=draft_cfg if speculative else None,
            draft_params=draft_params if speculative else None,
            k_draft=k_draft, async_paging=async_paging,
            gather_ring=gather_ring)
        # per-tick prefill token budget of the batched packer, in
        # scan-chunk units (an admit dispatch costs one unit).  The
        # default lets every staging row take a full scan + admit per
        # tick — the batched path is then never slower than the
        # per-prompt one-chunk-per-entry loop it replaces.
        C = self.executor.prefill_chunk
        from repro.serving.executor import _MAX_SCAN_CHUNKS
        self.prefill_budget = prefill_budget
        self._budget_chunks = (
            max(1, prefill_budget // C) if prefill_budget is not None
            else self.executor.staging_depth * (_MAX_SCAN_CHUNKS + 1))
        self._max_scan_chunks = _MAX_SCAN_CHUNKS
        self.free: Deque[int] = deque(range(max_slots))
        self.active: Dict[int, Request] = {}
        self.queue: Deque[Request] = deque()
        self._all: List[Request] = []
        # staging state machine: FIFO of in-flight staged prefills, one per
        # executor ring buffer (free ring indices in _free_bufs); batched
        # rows whose request finished at admit wait in _dirty_rows until a
        # multi-row scatter release-zeroes them
        self._stagings: List[_Staging] = []
        self._free_bufs: Deque[int] = deque(range(self.staging_depth))
        self._dirty_rows: set = set()
        # state paging: host store of swapped-out requests (rid-keyed —
        # submit enforces rid uniqueness among live requests) and the
        # FIFO resume queue of rids waiting for a slot grant
        self.swap_policy = swap_policy
        self.idle_swap_ms = idle_swap_ms
        self.max_live_requests = max_live_requests
        self.swapped: Dict[int, _Swapped] = {}
        self.resume_q: Deque[int] = deque()
        self._grant_resume_next = True
        # disaggregated serving: a role="prefill" engine pauses every
        # request at the admit boundary (prompt fully prefilled, first
        # token emitted, sampler row advanced) and parks the swap record
        # here until the router ships it to a decode engine
        self._handoff_q: Deque[int] = deque()
        self.handoffs_out = 0       # records shipped via withdraw_handoff
        # async paging: rids whose gather is still draining D2H, in
        # dispatch order — the force-harvest order when the gather ring
        # runs out of buffers
        self.async_paging = bool(async_paging)
        self._draining_q: Deque[int] = deque()
        # spill-to-disk tier: beyond host_swap_bytes of in-memory swapped
        # images, the coldest dormant image spills to a wire-encoded file under
        # swap_spool_dir (a spool dir with no watermark spills every
        # dormant image — watermark 0)
        self.host_swap_bytes = host_swap_bytes
        self.swap_spool_dir = swap_spool_dir
        # speculative tick pipeline: drafts for the NEXT tick are
        # dispatched at the END of step() (async JAX dispatch overlaps
        # the draft with host-side emission/admit work — the serving
        # analogue of the paper's phase pipelining), so a pending
        # (k, device draft tokens, live-rid snapshot) record spans the
        # step() boundary; pauses/preempts arriving while it is pending
        # are deferred to the verify boundary (see pause())
        self._pending = None
        self._spec_deferred: List[tuple] = []   # (rid, resume_flag)
        self.spec_ticks = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.draft_prefills = 0     # draft-state rebuild dispatches
        self.ticks = 0
        self.decode_s = 0.0         # wall time inside decode ticks (+ sync)
        self.decoded_tokens = 0     # tokens emitted by ticks (not admit)
        self.stage_dispatches = 0   # prefill-chunk programs dispatched
        self.scatter_dispatches = 0  # slot-scatter programs dispatched
        self.swap_outs = 0          # slot/staging gathers to host
        self.swap_ins = 0           # restores through the slot scatter
        self.swap_s = 0.0           # wall time inside swap transfers
        self.swap_bytes = 0         # bytes moved (both directions)
        # swap_s split: dispatch = async program/put launches + harvests
        # of already-drained transfers (work the tick loop never waits
        # on); stall = blocking waits async paging exists to hide
        # (forced/sync harvests, inline puts).  Invariant:
        # swap_s == swap_dispatch_s + swap_stall_s.
        self.swap_dispatch_s = 0.0
        self.swap_stall_s = 0.0
        # direction breakdown (gather+harvest / put / scatter — sums to
        # swap_s too; benchmarks report these in µs)
        self.swap_gather_s = 0.0
        self.swap_put_s = 0.0
        self.swap_scatter_s = 0.0
        self.swap_prefetches = 0    # restore triples prestaged ahead
        self.swap_prefetch_hits = 0  # grants that consumed a prefetch
        self.swap_prefetch_drops = 0  # prefetches cancelled un-consumed
        self.swap_harvests_overlapped = 0  # drain done before harvest
        self.swap_harvests_forced = 0      # harvest had to block
        self.spills = 0             # images written to the spool dir
        self.spill_loads = 0        # images read back on resume
        self.spill_bytes = 0        # bytes written to disk
        self._metrics_seen: set = set()  # id() of requests already
                                    # counted before reset_metrics

    # ---------------------------------------------------- compat surface
    @property
    def spec(self):
        return self.executor.spec

    @property
    def prefill_chunk(self) -> int:
        return self.executor.prefill_chunk

    @property
    def plan_mode(self) -> str:
        return self.executor.plan_mode

    @property
    def prefill_batching(self) -> bool:
        return self.executor.prefill_batching

    @property
    def staging_depth(self) -> int:
        return self.executor.staging_depth

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def state_bytes_per_slot(self) -> int:
        return self.executor.state_bytes_per_slot

    @property
    def window_bytes_per_slot(self) -> int:
        return self.executor.window_bytes_per_slot

    @property
    def cache_bytes(self) -> int:
        return self.executor.cache_bytes

    @property
    def caches(self):
        return self.executor.caches

    @property
    def tokens(self):
        return self.executor.tokens

    @property
    def sampler(self):
        return self.executor.sampler

    @property
    def _staging(self) -> Optional[Request]:
        """Head-of-line staged request (back-compat view of the ring)."""
        return self._stagings[0].req if self._stagings else None

    # ------------------------------------------------------------ submit
    def submit(self, req: Request):
        # a decode-role engine never prefills: fresh prompts belong on a
        # prefill/both engine — it only adopts admitted state through
        # readmit_swapped (the prefill→decode handoff)
        if getattr(self, "role", "both") == "decode":
            raise ValueError(f"req {req.rid}: engine role is 'decode' — "
                             f"it accepts handoff images "
                             f"(readmit_swapped), not fresh prompts")
        # reject out-of-range sampling params up front: past this point the
        # host mirror and the device pipeline must behave identically
        if not 0.0 < req.top_p <= 1.0:
            raise ValueError(f"req {req.rid}: top_p must be in (0, 1], "
                             f"got {req.top_p}")
        if req.top_k < 0:
            raise ValueError(f"req {req.rid}: top_k must be >= 0, "
                             f"got {req.top_k}")
        if req.temperature <= 0.0 and (req.top_k > 0 or req.top_p < 1.0):
            raise ValueError(f"req {req.rid}: top_k/top_p have no effect "
                             f"at temperature<=0 (greedy); set "
                             f"temperature > 0")
        if req.max_new_tokens < 1:
            raise ValueError(f"req {req.rid}: max_new_tokens must be >= 1 "
                             f"(admit always emits the first token), got "
                             f"{req.max_new_tokens}")
        T = req.prompt_len
        if T is None:
            raise ValueError(f"req {req.rid}: needs a prompt or "
                             f"prompt_embeds")
        if T < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if T > self.max_len:
            raise ValueError(
                f"req {req.rid}: prompt length {T} exceeds max_len "
                f"{self.max_len} — the window caches would wrap "
                f"mid-prompt and silently corrupt the context")
        if self.speculative and req.prompt is None:
            raise ValueError(
                f"req {req.rid}: prompt_embeds requests cannot run on a "
                f"speculative engine — the draft-state rebuild at slot "
                f"activation (draft_prefill_slot) replays the consumed "
                f"*token* stream, and embeds have no token ids to "
                f"replay; submit to a non-speculative engine")
        # the swap store and resume queue are keyed by rid, so a rid must
        # be unique among the engine's LIVE requests (finished rids may
        # recur — sessions reconnect)
        if req.rid in self.swapped or any(
                r.rid == req.rid and not r.done for r in self._all):
            raise ValueError(f"req {req.rid}: rid already live on this "
                             f"engine (swap bookkeeping is rid-keyed)")
        if self.max_live_requests is not None:
            live = (len(self.queue) + len(self._stagings)
                    + len(self.active) + len(self.swapped))
            if live >= self.max_live_requests:
                raise RuntimeError(
                    f"max_live_requests={self.max_live_requests} reached "
                    f"({live} live incl. swapped): admission refused — "
                    f"oversubscription caps host memory, not just slots")
        req.t_submit = time.perf_counter()
        req.t_last_activity = req.t_submit
        req.state = QUEUED
        self.queue.append(req)
        self._all.append(req)

    def withdraw(self, *, oldest: bool = False) -> Optional[Request]:
        """Remove and return a queued (not yet staging) request, or None.
        Used by the router to move backlog across engines: rebalance
        steals the *newest* (default — the head of the queue keeps its
        FIFO TTFT), drain migrates *oldest*-first so arrival order
        survives the full-queue move."""
        if not self.queue:
            return None
        req = self.queue.popleft() if oldest else self.queue.pop()
        # identity removal (Request is a dataclass; two equal-field
        # requests must not alias)
        idx = next(i for i, r in enumerate(self._all) if r is req)
        del self._all[idx]
        return req

    def readmit(self, req: Request):
        """Put a withdrawn request back at the queue tail (router's undo
        when no other engine can accept it); t_submit is preserved."""
        self.queue.append(req)
        self._all.append(req)

    def withdraw_swapped(self) -> Optional[_Swapped]:
        """Remove and return the *newest* resuming request's swap record
        (request + host-side device image), or None.  The image is plain
        host numpy in the topology-free staging layout, so the router
        can migrate a resume claim to any engine with the same arch
        config — swap-aware rebalance.  Newest-first keeps the FIFO head
        of this engine's resume queue (same rationale as ``withdraw``).

        Migration waits for harvest: a still-draining gather is
        force-harvested and a spilled image reloaded, so the record
        leaves with a complete in-memory image; a prestaged prefetch is
        device-resident on THIS engine's mesh and is dropped."""
        if not self.resume_q:
            return None
        rid = self.resume_q.pop()
        rec = self.swapped.pop(rid)
        if rec.pending is not None:
            self._harvest(rec, forced=not rec.pending.ready())
        if rec.spool is not None:
            self._load_spill(rec)
        self._drop_prefetch(rec)
        idx = next(i for i, r in enumerate(self._all)
                   if r is rec.req)
        del self._all[idx]
        return rec

    def withdraw_handoff(self) -> Optional[_Swapped]:
        """Remove and return the oldest completed-prefill swap record
        awaiting dispatch to a decode engine, or None.  Only meaningful
        on a ``role="prefill"`` engine — ``_swap_out_ready`` parks every
        admit-boundary swap it makes on the handoff queue.  Like
        ``withdraw_swapped``, the record leaves with a complete
        in-memory image (a still-draining gather is force-harvested, a
        spilled image reloaded); under async paging the D2H drain has
        normally already overlapped the prefill ticks that followed the
        swap-out, so the harvest here is a copy-out, not a stall."""
        while self._handoff_q:
            rid = self._handoff_q.popleft()
            rec = self.swapped.pop(rid, None)
            if rec is None:
                continue            # withdrawn through another path
            if rec.pending is not None:
                self._harvest(rec, forced=not rec.pending.ready())
            if rec.spool is not None:
                self._load_spill(rec)
            self._drop_prefetch(rec)
            idx = next(i for i, r in enumerate(self._all)
                       if r is rec.req)
            del self._all[idx]
            self.handoffs_out += 1
            return rec
        return None

    def readmit_swapped(self, rec: _Swapped):
        """Adopt a migrated swap record: the request joins this engine's
        resume queue and its image is restored through this engine's
        slot scatter at the next grant (re-sharded to this engine's mesh
        by ``restore_slot``)."""
        if rec.req.rid in self.swapped or any(
                r.rid == rec.req.rid and not r.done for r in self._all):
            raise ValueError(f"req {rec.req.rid}: rid already live on "
                             f"this engine")
        self._all.append(rec.req)
        self.swapped[rec.req.rid] = rec
        self.resume_q.append(rec.req.rid)
        rec.req.state = RESUMING

    @property
    def load(self) -> int:
        """Requests this engine still owes work to (router placement).
        Resuming requests claim a slot grant; dormant swapped ones cost
        only host memory and are excluded."""
        return (len(self.active) + len(self.queue) + len(self._stagings)
                + len(self.resume_q))

    # ----------------------------------------------- router-facing surface
    # Narrow read surface the Router uses instead of reaching into the
    # engine's internals — an ``EngineProxy`` mirrors exactly these from
    # its worker's status snapshots, so local engines and process-remote
    # workers are interchangeable behind the router.
    @property
    def handoffs(self) -> int:
        """Completed-prefill swap records awaiting handoff dispatch."""
        return len(self._handoff_q)

    @property
    def queue_len(self) -> int:
        return len(self.queue)

    @property
    def free_slots(self) -> int:
        return len(self.free)

    @property
    def staging_len(self) -> int:
        return len(self._stagings)

    @property
    def resume_len(self) -> int:
        return len(self.resume_q)

    @property
    def idle_capacity(self) -> int:
        """Free slots not already claimed by the engine's own backlog
        (queue, staging ring, or resume queue — a resuming request owns
        the next freed slot just as surely as a staged-ready one)."""
        return (self.free_slots - self.queue_len - self.staging_len
                - self.resume_len)

    def owns(self, rid: int) -> bool:
        """True when a live (not done) request with ``rid`` is resident
        here — queued, staging, active, resuming or swapped out."""
        return rid in self.swapped or any(
            r.rid == rid and not r.done for r in self._all)

    def done_requests(self) -> List[Request]:
        return [r for r in self._all if r.done]

    def _finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # ------------------------------------------------------ state paging
    def pause(self, rid: int) -> Request:
        """Swap request ``rid`` out of device residency (its client went
        idle).  Wherever the request is in the lifecycle:

          * active       -> ONE gather program slices its cache column,
                            sampler row and last token to host; the slot
                            is freed;
          * staged-ready -> its staging row/buffer is gathered — it
                            never takes a slot;
          * mid-prefill  -> marked pause-pending: the chunk plan finishes
                            first and the swap happens at the admit
                            boundary (a partial prefill has no
                            admit-advanced sampler row to gather);
          * queued       -> removed from the queue; nothing is resident,
                            so the record's device image is None;
          * resuming     -> dropped from the resume queue back to
                            dormant (its image stays on host).

        On a speculative engine, pausing an active request while a draft
        is in flight (dispatched at the end of the previous tick) defers
        the swap to the next verify boundary — the mid-prefill deferral
        pattern applied to decode: between draft and verify the slot's
        residency is not a self-consistent image (its committed state
        trails un-verified proposals), so gathering it would capture
        state a later resume could not bitwise-continue from.  The
        request stays ACTIVE (and may emit the in-flight tick's verified
        tokens) until the next ``step`` verifies, then swaps out; a
        ``resume`` before that boundary just cancels the deferral.

        The request stays dormant until ``resume(rid)``; dormant
        requests do not block ``run_until_done``."""
        if rid in self.swapped:
            rec = self.swapped[rid]
            if rid in self.resume_q:
                self.resume_q.remove(rid)
                self._drop_prefetch(rec)    # cancelled resume: the
                # prestaged device image is dropped cleanly
                rec.req.state = SWAPPED
                return rec.req
            raise ValueError(f"req {rid} is already swapped out")
        for slot, req in self.active.items():
            if req.rid == rid:
                if self._pending is not None:
                    if not any(r == rid for r, _ in self._spec_deferred):
                        self._spec_deferred.append((rid, False))
                    return req      # swaps at the verify boundary
                return self._swap_out_active(slot)
        for st in self._stagings:
            if st.req.rid == rid:
                if st.ready:
                    self._swap_out_ready(st)
                else:
                    st.pause_pending = True
                return st.req
        for req in self.queue:
            if req.rid == rid:
                self.queue = deque(r for r in self.queue if r is not req)
                self.swapped[rid] = _Swapped(
                    req=req, state=None, t_swap=time.perf_counter())
                req.state = SWAPPED
                return req
        raise KeyError(f"no live request with rid {rid} to pause")

    def resume(self, rid: int) -> Request:
        """Bring a paused request back.  One that was swapped from the
        queue (no device image) rejoins the queue tail and re-prefills;
        one with a gathered image joins the resume queue and is swapped
        into the next granted slot — oldest-first, alternating fairly
        with staged-ready fresh admits.  A pending pause that has not
        reached its admit boundary yet is simply cancelled."""
        rec = self.swapped.get(rid)
        if rec is None:
            for i, (r, _res) in enumerate(self._spec_deferred):
                if r == rid:        # deferred mid-draft pause: cancel it
                    del self._spec_deferred[i]
                    return next(q for q in self.active.values()
                                if q.rid == rid)
            for st in self._stagings:
                if st.req.rid == rid and st.pause_pending:
                    st.pause_pending = False
                    return st.req
            raise KeyError(f"req {rid} is not swapped out")
        if rid in self.resume_q:
            raise ValueError(f"req {rid} is already resuming")
        req = rec.req
        if (rec.state is None and rec.pending is None
                and rec.spool is None):
            now = time.perf_counter()
            req.swapped_s += now - rec.t_swap
            req._swapped_pre_first_s += now - rec.t_swap
            del self.swapped[rid]
            self.queue.append(req)
            req.state = QUEUED
            req.t_last_activity = now
        else:
            self.resume_q.append(rid)
            req.state = RESUMING
        return req

    def preempt(self, rid: Optional[int] = None) -> Optional[Request]:
        """Evict an active request to host memory and queue it for
        automatic resume.  With ``rid`` the victim is explicit;
        otherwise the policy victim: lowest priority, ties broken by
        most recent slot activation (the oldest resident is evicted
        last — re-prefill/requeue work already sunk is protected).
        Returns the evicted request, or None when no slot is occupied.
        Like ``pause``, a preempt arriving while a speculative draft is
        in flight is deferred to the verify boundary (with automatic
        resume preserved)."""

        def _defer(req):
            if not any(r == req.rid for r, _ in self._spec_deferred):
                self._spec_deferred.append((req.rid, True))
            return req

        if rid is not None:
            for slot, req in self.active.items():
                if req.rid == rid:
                    if self._pending is not None:
                        return _defer(req)
                    return self._swap_out_active(slot, resume=True)
            raise KeyError(f"req {rid} is not active")
        if not self.active:
            return None
        slot = self._victim_slot()
        if self._pending is not None:
            return _defer(self.active[slot])
        return self._swap_out_active(slot, resume=True)

    def touch(self, rid: int):
        """Refresh request ``rid``'s activity lease — the idle policy
        swaps out active requests whose lease is older than
        ``idle_swap_ms``; a connected client calls this to keep its
        slot."""
        for r in self._all:
            if r.rid == rid and not r.done:
                r.t_last_activity = time.perf_counter()
                return
        raise KeyError(f"no live request with rid {rid}")

    def _victim_slot(self) -> int:
        return min(self.active,
                   key=lambda s: (self.active[s].priority,
                                  -(self.active[s]._t_active or 0.0)))

    def _ensure_gather_capacity(self):
        """Make room for one more async gather dispatch: when every
        gather-ring buffer is draining, force-harvest the oldest drain —
        the ledger guarantee that a draining buffer is never reused
        before harvest, paid for as stall instead of corruption."""
        while not self.executor._gather_free:
            self._harvest(self.swapped[self._draining_q[0]], forced=True)

    def _harvest(self, rec: _Swapped, *, forced: bool):
        """Materialize a DRAINING record's host image.  ``forced`` means
        the tick loop is blocking on it (sync path, ring pressure, or a
        grant that beat the drain) — that wait is the stall async paging
        exists to hide; an un-forced harvest found the transfer already
        complete and costs only the host-side copy-out."""
        t0 = time.perf_counter()
        rec.state = self.executor.harvest(rec.pending)
        dt = time.perf_counter() - t0
        rec.pending = None
        self._draining_q.remove(rec.req.rid)
        self.swap_s += dt
        self.swap_gather_s += dt
        if forced:
            self.swap_stall_s += dt
            self.swap_harvests_forced += 1
        else:
            self.swap_dispatch_s += dt
            self.swap_harvests_overlapped += 1

    def _harvest_sweep(self):
        """Tick-boundary harvest of every drain whose D2H transfer has
        completed — the background traffic lands without ever blocking
        decode."""
        for rid in list(self._draining_q):
            rec = self.swapped[rid]
            if rec.pending.ready():
                self._harvest(rec, forced=False)

    def flush_swaps(self):
        """Harvest ALL draining swap-outs now (tests/benches, and any
        caller that wants to inspect ``.state`` deterministically).
        Completed drains harvest as overlapped; incomplete ones stall."""
        while self._draining_q:
            rec = self.swapped[self._draining_q[0]]
            self._harvest(rec, forced=not rec.pending.ready())

    def _swap_out_active(self, slot: int, *, resume: bool = False):
        req = self.active.pop(slot)
        t0 = time.perf_counter()
        self._ensure_gather_capacity()
        pend = self.executor.gather_slot_async(slot)
        t1 = time.perf_counter()
        self.swap_s += t1 - t0
        self.swap_dispatch_s += t1 - t0
        self.swap_gather_s += t1 - t0
        self.swap_outs += 1
        self.swap_bytes += pend.nbytes
        self.free.append(slot)
        # t_swap is the DISPATCH stamp: parked-time exclusion spans
        # dispatch -> restore scatter, so overlapping the drain cannot
        # inflate reported TTFT/throughput
        rec = _Swapped(req=req, state=None, t_swap=t0, pending=pend)
        self.swapped[req.rid] = rec
        self._draining_q.append(req.rid)
        if not self.async_paging:
            self._harvest(rec, forced=True)     # sync fallback: block now
        if resume:
            self.resume_q.append(req.rid)
            req.state = RESUMING
        else:
            req.state = SWAPPED
        return req

    def _swap_out_ready(self, st: _Staging):
        """Admit-boundary swap: the request has its first token and an
        advanced sampler row, but no slot — gather the staging row
        instead of a slot column."""
        req = st.req
        t0 = time.perf_counter()
        self._ensure_gather_capacity()
        if self.executor.prefill_batching:
            pend = self.executor.bgather_row_async(st.buf)
            self._dirty_rows.add(st.buf)  # release-zeroed, then freed
        else:
            pend = self.executor.gather_staging_async(st.buf)
            self._free_bufs.append(st.buf)
        t1 = time.perf_counter()
        self.swap_s += t1 - t0
        self.swap_dispatch_s += t1 - t0
        self.swap_gather_s += t1 - t0
        self.swap_outs += 1
        self.swap_bytes += pend.nbytes
        self._stagings.remove(st)
        rec = _Swapped(req=req, state=None, t_swap=t0, pending=pend)
        self.swapped[req.rid] = rec
        self._draining_q.append(req.rid)
        if not self.async_paging:
            self._harvest(rec, forced=True)
        req.state = SWAPPED
        if self.role == "prefill":
            # disaggregation: every admit-boundary swap on a prefill
            # engine is a finished prefill whose image belongs on a
            # decode engine — park it for the router's handoff sweep
            self._handoff_q.append(req.rid)

    def _swap_in(self, rid: int, slot: int):
        rec = self.swapped.pop(rid)
        req = rec.req
        if rec.pending is not None:     # grant beat the drain
            self._harvest(rec, forced=not rec.pending.ready())
        if rec.spool is not None:
            self._load_spill(rec)
        t0 = time.perf_counter()
        if rec.prefetch is not None:
            prestaged, rec.prefetch = rec.prefetch, None
            self.swap_prefetch_hits += 1
            t1 = t0
        else:
            # inline put: the stall a prefetched grant avoids
            prestaged = self.executor.prestage_restore(rec.state)
            t1 = time.perf_counter()
            self.swap_s += t1 - t0
            self.swap_stall_s += t1 - t0
            self.swap_put_s += t1 - t0
        self.executor.restore_slot(slot, rec.state, prestaged=prestaged)
        self.scatter_dispatches += 1
        now = time.perf_counter()
        self.swap_s += now - t1
        self.swap_dispatch_s += now - t1
        self.swap_scatter_s += now - t1
        self.swap_ins += 1
        self.swap_bytes += rec.state.nbytes
        req.swapped_s += now - rec.t_swap
        self.active[slot] = req
        req.state = ACTIVE
        req._t_active = now
        req.t_last_activity = now
        self._draft_activate(slot, req)

    def _prefetch_resume(self):
        """Prestage the head resume claim's H2D put one tick ahead of a
        *predictable* grant (a slot is already free, or some active slot
        is within one tick of its budget) so the grant-boundary scatter
        consumes an already-device-resident image.  A cancelled resume
        just drops the triple (``pause``/``withdraw_swapped``)."""
        if not self.resume_q:
            return
        rec = self.swapped[self.resume_q[0]]
        if rec.prefetch is not None:
            return
        if not (self.free or any(
                r.max_new_tokens - len(r.output) <= self.decode_block
                for r in self.active.values())):
            return
        if rec.pending is not None:
            if not rec.pending.ready():
                return              # draining: let the D2H finish first
            self._harvest(rec, forced=False)
        if rec.spool is not None:
            self._load_spill(rec)
        t0 = time.perf_counter()
        rec.prefetch = self.executor.prestage_restore(rec.state)
        dt = time.perf_counter() - t0
        self.swap_s += dt
        self.swap_dispatch_s += dt
        self.swap_put_s += dt
        self.swap_prefetches += 1

    def _drop_prefetch(self, rec: _Swapped):
        if rec.prefetch is not None:
            rec.prefetch = None
            self.swap_prefetch_drops += 1

    # ---------------------------------------------------- spill-to-disk
    def _spill_path(self, rid: int) -> str:
        return os.path.join(self.swap_spool_dir, f"swap-{rid}.state")

    def _apply_spill(self):
        """Push the coldest dormant images out to the spool dir until
        in-memory swapped bytes fit under the ``host_swap_bytes``
        watermark.  Only images nothing is about to touch are eligible:
        not draining, not prefetched, not queued for resume."""
        limit = self.host_swap_bytes or 0
        while True:
            held = [r for r in self.swapped.values()
                    if r.state is not None]
            if sum(r.state.nbytes for r in held) <= limit:
                return
            cold = [r for r in held
                    if r.req.rid not in self.resume_q
                    and r.prefetch is None]
            if not cold:
                return
            self._spill(min(cold, key=lambda r: r.t_swap))

    def _spill(self, rec: _Swapped):
        """Spool-tier writer: the on-disk image is the wire encoding
        (``serving.wire`` — the SAME serializer the RPC migration path
        uses), treedef included, so nothing about a spilled session
        stays pinned in host memory."""
        os.makedirs(self.swap_spool_dir, exist_ok=True)
        path = self._spill_path(rec.req.rid)
        wire.dump_swapped(path, rec.state)
        rec.spool = path
        self.spills += 1
        self.spill_bytes += rec.state.nbytes
        rec.state = None

    def _load_spill(self, rec: _Swapped):
        """Transparent reload on resume: rebuild the ``SwappedState``
        from the spool file (bitwise — the wire codec frames every
        array with its exact dtype/shape) and delete it."""
        rec.state = wire.load_swapped(rec.spool)
        os.remove(rec.spool)
        rec.spool = None
        self.spill_loads += 1

    def _grant_resume(self) -> bool:
        """True when the next freed slot goes to the resume queue rather
        than a staged-ready fresh admit.  When both classes wait, grants
        strictly alternate — neither resumed sessions nor fresh prompts
        starve the other."""
        if not self.resume_q:
            return False
        if not (self._stagings and self._stagings[0].ready):
            return True
        return self._grant_resume_next

    def _apply_swap_policy(self):
        """Tick-boundary eviction sweep (``swap_policy != "manual"``).

        idle: an active request whose lease (``t_last_activity``) is
        older than ``idle_swap_ms`` is swapped out dormant — the serving
        analog of a chat session gone quiet; it re-enters via
        ``resume``.

        pressure: while a *strictly* higher-priority request waits
        (resume queue, staged-ready or queued) without a free slot, the
        lowest-priority active request is evicted to the resume queue.
        Strict inequality is the anti-thrash guard: equal priorities
        never displace each other."""
        now = time.perf_counter()
        if self.swap_policy in ("idle", "auto"):
            cutoff = self.idle_swap_ms / 1e3
            for slot in [s for s, r in self.active.items()
                         if now - r.t_last_activity > cutoff]:
                self._swap_out_active(slot)
        if self.swap_policy in ("pressure", "auto"):
            while self.active:
                waiting = sorted(
                    [self.swapped[r].req.priority for r in self.resume_q]
                    + [s.req.priority for s in self._stagings if s.ready]
                    + [r.priority for r in self.queue], reverse=True)
                if len(self.free) >= len(waiting):
                    break
                # highest-priority waiter not already covered by a free
                # slot; each eviction frees one, so the walk terminates
                need = waiting[len(self.free)]
                slot = self._victim_slot()
                if need <= self.active[slot].priority:
                    break
                self._swap_out_active(slot, resume=True)

    # ----------------------------------------------------------- staging
    def _stage_start(self, req: Request):
        buf = self._free_bufs.popleft()
        req.state = STAGING
        # prefill role: swap out at the admit boundary instead of holding
        # the request staged-ready — the same pause-pending machinery a
        # mid-prefill pause() uses, so the image is complete (prompt
        # consumed, first token emitted, sampler row advanced) and the
        # finished-at-admit check still completes EOS / 1-token requests
        # in place, no handoff needed
        handoff = self.role == "prefill"
        if self.executor.prefill_batching:
            # batched path: no fixed plan — the per-tick packer allocates
            # chunks; begin is host-only (rows are release-zeroed by the
            # multi-row scatter, so starting a staging costs no dispatch)
            T = req.prompt_len
            C = self.executor.prefill_chunk
            tail = (T - 1) % C + 1
            self._stagings.append(_Staging(
                req=req, plan=[], buf=buf,
                chunks_left=(T - tail) // C, tail=tail,
                pause_pending=handoff))
            self.executor.bstage_begin(
                buf, seed=self.seed, rid=req.rid,
                temperature=req.temperature, top_k=req.top_k,
                top_p=req.top_p, eos_id=req.eos_id,
                budget=req.max_new_tokens)
            return
        self._stagings.append(_Staging(
            req=req, plan=self.executor.plan_prefill(req.prompt_len),
            buf=buf, pause_pending=handoff))
        self.executor.stage_begin(
            buf, seed=self.seed, rid=req.rid, temperature=req.temperature,
            top_k=req.top_k, top_p=req.top_p, eos_id=req.eos_id,
            budget=req.max_new_tokens)

    def _stage_dispatch_one(self, st: _Staging):
        step = st.plan[st.plan_pos]
        chunk = st.req._inputs[st.prompt_pos:st.prompt_pos + step.tokens]
        if step.kind == "scan":
            self.executor.stage_chunk_scan(st.buf, chunk,
                                           valid_lens=step.valid)
        elif step.kind == "chunk":
            self.executor.stage_chunk(st.buf, chunk)
        else:
            self.executor.stage_admit(st.buf, chunk, valid_len=step.valid)
        st.prompt_pos += step.tokens
        st.plan_pos += 1
        self.stage_dispatches += 1

    def _stage_finish(self, st: _Staging):
        """Plan complete: sync the fused first token (this is the
        device-confirmed admit — TTFT is stamped here, not when the
        dispatch was queued) and either complete the request (EOS /
        max_new_tokens=1, never occupying a slot) or hold it staged-ready
        until a slot frees."""
        req = st.req
        tok = int(np.asarray(self.executor.staging_tok[st.buf])[0])
        req.t_first = time.perf_counter()
        req.output.append(tok)
        if self._finished(req, tok):
            req.done = True
            req.state = DONE
            req.t_done = req.t_first
            self._stagings.remove(st)
            self._free_bufs.append(st.buf)
            return
        if st.pause_pending:
            self._swap_out_ready(st)    # the admit-boundary swap
            return
        st.ready = True
        req.state = READY

    def _stage_scatter(self):
        st = self._stagings.pop(0)
        slot = self.free.popleft()
        self.executor.scatter(slot, st.buf)
        self.scatter_dispatches += 1
        self._free_bufs.append(st.buf)
        self.active[slot] = st.req
        self._activate(st.req)
        self._draft_activate(slot, st.req)

    def _activate(self, req: Request):
        req.state = ACTIVE
        now = time.perf_counter()
        req._t_active = now
        req.t_last_activity = now

    def _draft_activate(self, slot: int, req: Request):
        """Rebuild the draft model's per-slot state at every slot
        activation (fresh admit and swap-in alike) by replaying the
        request's consumed tokens — prompt plus every emitted token
        except the last, which is the next decode input.  This is what
        keeps the swap image draft-free: a speculative engine's
        ``SwappedState`` is byte-identical to a non-speculative one's,
        and the draft residency is reconstructed in ONE fixed-shape
        dispatch."""
        if not self.speculative:
            return
        toks = np.asarray(req.prompt, np.int32).reshape(-1)
        if len(req.output) > 1:
            toks = np.concatenate(
                [toks, np.asarray(req.output[:-1], np.int32)])
        self.executor.draft_prefill_slot(slot, toks)
        self.draft_prefills += 1

    # --------------------------------------------------- batched staging
    def _flush_scatter(self, assigns):
        """One multi-row scatter covering every slot assignment plus the
        dirty (finished-at-admit) rows; released rows return to the free
        pool clean."""
        rows = [row for _, row in assigns]
        self.executor.bscatter(assigns, self._dirty_rows)
        self.scatter_dispatches += 1
        for row in rows:
            self._free_bufs.append(row)
        for row in self._dirty_rows:
            self._free_bufs.append(row)
        self._dirty_rows.clear()

    def _stage_finish_batch(self, sts: List[_Staging]):
        """Every request admitted by one batched dispatch syncs its first
        token from the SAME device-confirmed read and stamps the SAME
        ``t_first`` — a batch admit is one device event, so serial
        per-entry stamps would skew TTFT for all but the first row."""
        toks = np.asarray(self.executor.btoks)      # the one host sync
        now = time.perf_counter()
        for st in sts:
            req = st.req
            tok = int(toks[st.buf])
            req.t_first = now
            req.output.append(tok)
            if self._finished(req, tok):
                req.done = True
                req.state = DONE
                req.t_done = now
                self._stagings.remove(st)
                self._dirty_rows.add(st.buf)    # zeroed at next scatter
            elif st.pause_pending:
                self._swap_out_ready(st)        # the admit-boundary swap
            else:
                st.ready = True
                req.state = READY

    def _dispatch_batched(self, budget: int) -> bool:
        """One packed prefill round: walk the staging FIFO oldest-first,
        allocating each entry up to ``budget`` scan-chunk units (an admit
        costs one unit), then fuse all allocations into at most one
        batched scan + one batched admit dispatch per input kind.  The
        walk never skips past an unfinished older entry once the budget
        runs out — head-of-line (oldest-first) allocation is the
        fairness guard: a long staged prompt always drains at full rate,
        so its dispatch count is bounded by its own chunk count no matter
        how many short prompts arrive behind it.  Interior chunks are
        C-quantized (masks cover only tails and placeholder rows), so
        each prompt's chunk decomposition — and therefore its token
        stream — is bitwise that of per-prompt dispatch."""
        scan_e: Dict[bool, list] = {}
        admit_e: Dict[bool, list] = {}
        admitted: List[_Staging] = []
        for st in self._stagings:
            if st.ready or st.admitted:
                continue
            if budget <= 0:
                break               # strict oldest-first: no skip-ahead
            is_embeds = st.req.prompt is None
            if st.chunks_left:
                take = min(st.chunks_left, self._max_scan_chunks, budget)
                C = self.executor.prefill_chunk
                chunk = st.req._inputs[st.prompt_pos:
                                       st.prompt_pos + take * C]
                scan_e.setdefault(is_embeds, []).append(
                    (st.buf, chunk, take))
                st.prompt_pos += take * C
                st.chunks_left -= take
                budget -= take
            if st.chunks_left == 0 and budget > 0:
                chunk = st.req._inputs[st.prompt_pos:
                                       st.prompt_pos + st.tail]
                admit_e.setdefault(is_embeds, []).append(
                    (st.buf, chunk, st.tail))
                st.prompt_pos += st.tail
                st.admitted = True
                admitted.append(st)
                budget -= 1
        for entries in scan_e.values():
            self.executor.bstage_chunk_scan(entries)
            self.stage_dispatches += 1
        for entries in admit_e.values():
            self.executor.bstage_admit(entries)
            self.stage_dispatches += 1
        if admitted:
            self._stage_finish_batch(admitted)
        return bool(scan_e or admit_e)

    def _admit_batched(self):
        """Batched admit pipeline: per tick, at most ONE multi-row
        scatter, then new stagings (host-only), then one packed prefill
        round of at most one batched scan + one batched admit dispatch
        per input kind — dispatches per tick are O(1) in queue depth.
        While slots are free the loop drains work-conservingly (same
        admits as the serialized baseline); under saturation one round
        per tick keeps the resident slots decoding between prefill
        programs."""
        while True:
            progressed = False
            # slot grants: resume-queue swap-ins (restore through the
            # slot scatter, oldest first) interleave with the multi-row
            # scatter of head-run staged-ready requests — when both
            # classes wait, grants strictly alternate (FIFO within each)
            assigns = []
            while self.free and (self.resume_q
                                 or (self._stagings
                                     and self._stagings[0].ready)):
                if self._grant_resume():
                    self._swap_in(self.resume_q.popleft(),
                                  self.free.popleft())
                    self._grant_resume_next = False
                    progressed = True
                else:
                    st = self._stagings.pop(0)
                    slot = self.free.popleft()
                    assigns.append((slot, st.buf))
                    self.active[slot] = st.req
                    self._activate(st.req)
                    self._draft_activate(slot, st.req)
                    self._grant_resume_next = True
            if assigns:
                self._flush_scatter(assigns)
                progressed = True
            # start staging while rows allow; a dirty row blocks a start
            # only until a release-only scatter cleans it
            while (self.queue and (self.free or self.overlap)):
                if not self._free_bufs:
                    if self._dirty_rows:
                        self._flush_scatter([])
                        progressed = True
                        continue
                    break
                self._stage_start(self.queue.popleft())
                progressed = True
            # one packed prefill round; infinite budget while a slot is
            # free (work-conserving parity with the serialized baseline)
            budget = (self._budget_chunks if not self.free
                      else 1 << 30)
            if self._dispatch_batched(budget):
                progressed = True
            if not self.free and self.active:
                return              # saturated: one round per tick
            if not progressed:
                return

    def _admit(self):
        """Advance the admit pipeline at a tick boundary.

        Work-conserving: while free slots exist, queued requests prefill
        and scatter exactly as the serialized baseline does.  The overlap
        is purely additive — when every slot is busy, up to
        ``staging_depth`` head-of-queue requests *still* stream their
        chunk plans into the staging ring, **one chunk dispatch per
        staged request per tick** so the resident slots keep decoding
        between chunks, and emit their fused-sample first tokens at plan
        completion, held staged-ready until slots free (scattered in FIFO
        order).  Overlapped TTFT is therefore never structurally worse
        than serialized, and strictly better whenever a request would
        have had to wait for a slot before prefilling.

        With ``prefill_batching`` (the default when every mixer kind
        supports it) the per-entry loop is replaced by
        ``_admit_batched``: all staged prompts fuse into one batched
        program per dispatch and dispatches per tick are O(1) in queue
        depth."""
        if self.executor.prefill_batching:
            return self._admit_batched()
        yielded = set()     # stagings that already dispatched this tick
        while True:
            # resume swap-ins share freed slots with the FIFO scatter of
            # staged-ready requests (strict alternation under contention)
            if self.free and self._grant_resume():
                self._swap_in(self.resume_q.popleft(), self.free.popleft())
                self._grant_resume_next = False
                continue
            # FIFO scatter: the head staged-ready request takes the slot
            if self._stagings and self._stagings[0].ready:
                if self.free:
                    self._stage_scatter()
                    self._grant_resume_next = True
                    continue    # next queued request may start staging
            # start staging while ring buffers allow (serialized admit
            # waits for a free slot up front)
            if (self.queue and self._free_bufs
                    and (self.free or self.overlap)):
                self._stage_start(self.queue.popleft())
                continue
            st = next((s for s in self._stagings
                       if not s.ready and id(s) not in yielded), None)
            if st is None:
                return
            self._stage_dispatch_one(st)
            if st.plan_pos == len(st.plan):
                self._stage_finish(st)
            elif not self.free and self.active:
                yielded.add(id(st))     # ahead-of-slot: one chunk per tick
                                        # so the resident slots decode
                                        # between prefill chunks

    # -------------------------------------------------------------- tick
    def _tick_k(self) -> int:
        """Budget-aware tick length: smallest power-of-two bucket (capped
        at ``decode_block``) covering the largest remaining per-slot
        budget — the all-slots-finish-early tail stops burning masked
        scan steps, and bucketing bounds the program cache."""
        if not self.budget_ticks:
            return self.decode_block
        need = max(r.max_new_tokens - len(r.output)
                   for r in self.active.values())
        k = 1
        while k < need and k < self.decode_block:
            k <<= 1
        return min(k, self.decode_block)

    def _spec_k(self) -> int:
        """Budget-aware draft length: smallest power-of-two bucket (capped
        at ``k_draft``, and at the acceptance-adapted effective k when
        ``adaptive_k`` is on) covering the largest remaining budget
        *minus the verify's own guaranteed emission* — a slot with one
        token left needs no draft at all (k = 0 is a verify-only
        1-position tick)."""
        kmax = self._k_eff if self.adaptive_k else self.k_draft
        if not self.budget_ticks:
            return kmax
        need = max(r.max_new_tokens - len(r.output)
                   for r in self.active.values())
        if need <= 1:
            return 0
        k = 1
        while k < need - 1 and k < kmax:
            k <<= 1
        return min(k, kmax)

    def _adapt_k(self, accepted: int, drafted: int):
        """Acceptance-adaptive draft length: over a short window of
        draft-verify ticks, a collapsed acceptance rate halves the
        effective k (floor 1 — a verify tick always emits its own
        sample) and a high rate doubles it back (cap ``k_draft``).  Each
        adjustment clears the window so the next decision is measured at
        the new k.  Token streams are unaffected — the shared-key verify
        emits the same tokens at any k; only the drafted-but-rejected
        work per sync changes."""
        self._accept_window.append((accepted, drafted))
        if len(self._accept_window) < self._accept_window.maxlen:
            return
        d = sum(x[1] for x in self._accept_window)
        if d == 0:
            return
        rate = sum(x[0] for x in self._accept_window) / d
        if rate < 0.5 and self._k_eff > 1:
            self._k_eff = max(1, self._k_eff // 2)
            self._accept_window.clear()
        elif rate > 0.8 and self._k_eff < self.k_draft:
            self._k_eff = min(self.k_draft, self._k_eff * 2)
            self._accept_window.clear()

    def _step_speculative(self):
        """One speculative engine tick, pipelined across the step
        boundary: verify the draft dispatched at the END of the previous
        step (the tick's one host sync), emit, drain pause/preempt
        requests deferred to this verify boundary, then run the normal
        policy sweep + admit pipeline and dispatch the next draft.
        Admits, swap-ins and evictions therefore only ever happen
        *between* a verify and the next draft — a pending draft never
        straddles a slot-population change."""
        if self._pending is not None:
            k, dtoks, live = self._pending
            self._pending = None
            t0 = time.perf_counter()
            toks, valid = self.executor.spec_verify(k, dtoks)
            now = time.perf_counter()
            self.decode_s += now - t0
            self.ticks += 1
            self.spec_ticks += 1
            self.drafted_tokens += k * len(live)
            tick_accepted = 0
            for slot, req in list(self.active.items()):
                emitted = 0
                for j in range(toks.shape[0]):
                    if not valid[j, slot]:
                        break
                    tok = int(toks[j, slot])
                    req.output.append(tok)
                    self.decoded_tokens += 1
                    emitted += 1
                    if self._finished(req, tok):
                        req.done = True
                        req.state = DONE
                        req.t_done = now
                        del self.active[slot]
                        self.free.append(slot)
                        break
                # every emission beyond the first rode on an accepted
                # draft token (the first is the verify's own sample)
                tick_accepted += max(emitted - 1, 0)
            self.accepted_tokens += tick_accepted
            if self.adaptive_k and k > 0:
                self._adapt_k(tick_accepted, k * len(live))
            if self._spec_deferred:
                deferred, self._spec_deferred = self._spec_deferred, []
                for rid, res in deferred:
                    slot = next((s for s, r in self.active.items()
                                 if r.rid == rid), None)
                    if slot is not None:    # may have finished in verify
                        self._swap_out_active(slot, resume=res)
        if self.async_paging and self._draining_q:
            self._harvest_sweep()
        if self.swap_spool_dir is not None:
            self._apply_spill()
        if self.swap_policy != "manual":
            self._apply_swap_policy()
        self._admit()
        if self.async_paging:
            self._prefetch_resume()
        if not self.active:
            return
        k = self._spec_k()
        t0 = time.perf_counter()
        dtoks = self.executor.spec_draft(k)     # async — no host sync
        self.decode_s += time.perf_counter() - t0
        self._pending = (k, dtoks,
                         [r.rid for r in self.active.values()])

    def step(self):
        """One engine tick: advance the admit pipeline (free slots fill as
        in the serialized baseline, plus up to ``staging_depth``
        ahead-of-slot staged prefills when every slot is busy), then one
        fused decode+sample scan, then emit and free — a single host sync
        for the decode block.

        Speculative engines run the draft–verify tick instead (see
        ``_step_speculative``); the non-speculative path below is
        untouched."""
        if self.speculative:
            return self._step_speculative()
        if self.async_paging and self._draining_q:
            self._harvest_sweep()
        if self.swap_spool_dir is not None:
            self._apply_spill()
        if self.swap_policy != "manual":
            self._apply_swap_policy()
        self._admit()
        if self.async_paging:
            self._prefetch_resume()
        if not self.active:
            return
        k = self._tick_k()
        t0 = time.perf_counter()
        toks, valid = self.executor.decode(k)   # (k, S) — the one host sync
        now = time.perf_counter()
        self.decode_s += now - t0
        self.ticks += 1
        for slot, req in list(self.active.items()):
            for j in range(toks.shape[0]):
                if not valid[j, slot]:
                    break
                tok = int(toks[j, slot])
                req.output.append(tok)
                self.decoded_tokens += 1
                if self._finished(req, tok):
                    req.done = True
                    req.state = DONE
                    req.t_done = now
                    del self.active[slot]
                    self.free.append(slot)
                    break

    def run_until_done(self, max_ticks: int = 10_000, *,
                       strict: bool = True) -> List[Request]:
        """Tick until queue, staging ring, slots and resume queue drain.
        Dormant swapped-out requests (paused without resume) are NOT
        pending work — the loop returns with them still parked on
        host."""
        for _ in range(max_ticks):
            if (not self.queue and not self.active and not self._stagings
                    and not self.resume_q):
                break
            self.step()
        if (self.queue or self.active or self._stagings
                or self.resume_q):
            msg = (f"run_until_done: max_ticks={max_ticks} exhausted with "
                   f"{len(self.queue)} queued, {len(self.active)} active, "
                   f"{len(self._stagings)} staging, {len(self.resume_q)} "
                   f"resuming request(s) unfinished — raise max_ticks or "
                   f"inspect the engine")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning)
        return [r for r in self._all if r.done]

    # ----------------------------------------------------------- metrics
    def reset_metrics(self):
        """Zero the aggregate counters (benchmarks call this after a
        warm-up pass so compile time stays out of the measurement).

        The per-request window is marked by *completion*, not by
        submission: a request submitted (or paused) before the reset
        that finishes after it still counts.  The old watermark over
        ``_all`` assumed submit -> finish was one slot residency; a
        request can now sit swapped out across a reset."""
        self.ticks = 0
        self.decode_s = 0.0
        self.decoded_tokens = 0
        self.stage_dispatches = 0
        self.scatter_dispatches = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swap_s = 0.0
        self.swap_bytes = 0
        self.swap_dispatch_s = 0.0
        self.swap_stall_s = 0.0
        self.swap_gather_s = 0.0
        self.swap_put_s = 0.0
        self.swap_scatter_s = 0.0
        self.swap_prefetches = 0
        self.swap_prefetch_hits = 0
        self.swap_prefetch_drops = 0
        self.swap_harvests_overlapped = 0
        self.swap_harvests_forced = 0
        self.spills = 0
        self.spill_loads = 0
        self.spill_bytes = 0
        self.spec_ticks = 0
        self.drafted_tokens = 0
        self.accepted_tokens = 0
        self.draft_prefills = 0
        self.handoffs_out = 0
        self._metrics_seen = {id(r) for r in self._all if r.done}

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over requests completed since the
        last ``reset_metrics`` (all requests by default)."""
        done = [r for r in self._all
                if r.done and id(r) not in self._metrics_seen]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        mesh = self.executor.mesh
        progs = self.executor.compiled_programs()
        return {
            "requests": len(done),
            "tokens": sum(len(r.output) for r in done),
            "ticks": self.ticks,
            "decode_block": self.decode_block,
            "decoded_tokens": self.decoded_tokens,
            "decode_s": self.decode_s,
            "decode_us_per_token":
                self.decode_s / max(1, self.decoded_tokens) * 1e6,
            "stage_dispatches": self.stage_dispatches,
            "scatter_dispatches": self.scatter_dispatches,
            "overlap": int(self.overlap),
            "prefill_chunk": self.executor.prefill_chunk,
            "plan_mode": self.executor.plan_mode,
            "prefill_batching": int(self.executor.prefill_batching),
            "compiled_programs": progs["total"],
            "prefill_programs": progs["prefill"],
            "staging_depth": self.staging_depth,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swapped": len(self.swapped),
            "resuming": len(self.resume_q),
            "swap_s": self.swap_s,
            "swap_bytes": self.swap_bytes,
            "swap_us_per_mb": (self.swap_s * 1e6
                               / (self.swap_bytes / 2 ** 20)
                               if self.swap_bytes else 0.0),
            "swap_bytes_per_slot": self.executor.swap_bytes_per_slot,
            "async_paging": int(self.async_paging),
            "gather_ring": self.executor.gather_ring,
            "swap_dispatch_s": self.swap_dispatch_s,
            "swap_stall_s": self.swap_stall_s,
            "swap_gather_s": self.swap_gather_s,
            "swap_put_s": self.swap_put_s,
            "swap_scatter_s": self.swap_scatter_s,
            "swap_prefetches": self.swap_prefetches,
            "swap_prefetch_hits": self.swap_prefetch_hits,
            "swap_prefetch_drops": self.swap_prefetch_drops,
            "swap_harvests_overlapped": self.swap_harvests_overlapped,
            "swap_harvests_forced": self.swap_harvests_forced,
            "swap_overlap_ratio": (
                self.swap_harvests_overlapped
                / max(1, self.swap_harvests_overlapped
                      + self.swap_harvests_forced)),
            "draining_swaps": len(self._draining_q),
            "spills": self.spills,
            "spill_loads": self.spill_loads,
            "spill_bytes": self.spill_bytes,
            "host_swap_bytes_held": sum(
                r.state.nbytes for r in self.swapped.values()
                if r.state is not None),
            "role": self.role,
            "handoffs": len(self._handoff_q),
            "handoffs_out": self.handoffs_out,
            "speculative": int(self.speculative),
            "k_draft": self.k_draft if self.speculative else 0,
            "adaptive_k": int(self.adaptive_k),
            "k_draft_effective":
                (self._k_eff if self.speculative and self.adaptive_k
                 else (self.k_draft if self.speculative else 0)),
            "spec_ticks": self.spec_ticks,
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "acceptance_rate":
                self.accepted_tokens / max(1, self.drafted_tokens),
            "syncs_per_token": self.ticks / max(1, self.decoded_tokens),
            "draft_prefills": self.draft_prefills,
            "checkpoint_bytes_per_slot":
                (self.executor.checkpoint_bytes_per_slot
                 if self.speculative else 0),
            "draft_bytes_per_slot":
                (self.executor.draft_bytes_per_slot
                 if self.speculative else 0),
            "speculative_bytes":
                (self.executor.speculative_bytes
                 if self.speculative else 0),
            "mesh_data": int(mesh.shape["data"]) if mesh is not None else 1,
            "mesh_model": (int(mesh.shape["model"])
                           if mesh is not None else 1),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "mean_tokens_per_s": float(np.mean(tps)) if tps else 0.0,
        }
