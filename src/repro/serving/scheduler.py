"""Host scheduler: request lifecycle, slot assignment and tick policy.

This is the host half of the scheduler/executor split (the device half —
slot/staging buffers and every jitted program — is
``repro.serving.executor.DeviceExecutor``).  The scheduler never touches a
device buffer directly; it decides *what* to dispatch and *when*:

  1. **submit** validates a request (sampling parameters, token budget,
     prompt length vs ``max_len`` — an over-long prompt would wrap the
     rolling window caches mid-prompt and silently corrupt them) and
     appends it to a FIFO queue.
  2. **staging admit** (overlapped, the default): queued requests prefill
     *chunk by chunk* into the executor's staging ring at tick
     boundaries.  While free slots exist this is work-conserving (same
     admits as the serialized baseline); once every slot is busy, up to
     ``staging_depth`` head-of-queue requests still prefill ahead of any
     free slot — one chunk dispatch per staged request per tick — emit
     their first tokens (the final chunk fuses the draw on device — no
     host ``sample_np``), and are held staged-ready until slots free
     (scattered in FIFO order).  TTFT is stamped when that token is
     device-confirmed (synced to the host), not when the dispatch is
     queued.  With ``overlap=False`` the same programs run back-to-back
     behind a free slot (the serialized baseline — token streams are
     bitwise identical, only timing moves).
  3. **tick** (`step`): one fused decode+sample scan over all slots.  The
     tick length is **budget-aware**: the smallest power-of-two bucket
     (capped at ``decode_block``) covering the largest remaining per-slot
     budget, so the tail ticks of a batch of short budgets stop burning
     masked steps — bucketing bounds the compile cache.
  4. finished slots (device EOS/budget flags) are freed at tick boundaries.

With ``mesh`` set, the executor allocates every buffer with NamedShardings
(slot axis on "data", state heads / KV context on "model") and compiles
every program with explicit in/out shardings — the scheduler logic is
topology-blind; only the buffers underneath it are distributed.

Wall-clock metrics (TTFT, latency, throughput) are stamped per request;
``metrics()`` aggregates them plus the decode-only µs/token that
``benchmarks/bench_serving.py`` sweeps.
"""
from __future__ import annotations

import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving.executor import DeviceExecutor, PlanStep


@dataclass
class Request:
    rid: int
    prompt: Optional[np.ndarray] = None         # (T,) int32 token ids
    prompt_embeds: Optional[np.ndarray] = None  # (T, d_model) — stub
                                                # frontends (vlm/audio)
    max_new_tokens: int = 16
    temperature: float = 0.0            # 0 => greedy
    top_k: int = 0                      # 0 => disabled
    top_p: float = 1.0                  # 1.0 => disabled
    eos_id: Optional[int] = None
    output: List[int] = field(default_factory=list)
    done: bool = False
    # wall-clock stamps (perf_counter seconds), set by the engine
    t_submit: Optional[float] = None
    t_first: Optional[float] = None     # first token device-confirmed
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        if self.t_first is None or self.t_submit is None:
            return None
        return self.t_first - self.t_submit

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_done is None or self.t_submit is None:
            return None
        return self.t_done - self.t_submit

    @property
    def tokens_per_s(self) -> Optional[float]:
        lat = self.latency_s
        if not lat:
            return None
        return len(self.output) / lat

    @property
    def prompt_len(self) -> Optional[int]:
        if self.prompt is not None:
            return int(np.asarray(self.prompt).shape[-1])
        if self.prompt_embeds is not None:
            return int(np.asarray(self.prompt_embeds).shape[0])
        return None

    @property
    def _inputs(self):
        return self.prompt if self.prompt is not None else self.prompt_embeds


@dataclass(eq=False)      # identity semantics: entries are removed by `is`
class _Staging:
    """One in-flight staged prefill: a request bound to an executor ring
    buffer (= a batched staging row), with its chunk-plan progress and
    staged-ready flag.  The per-prompt path walks ``plan``; the batched
    path tracks ``chunks_left`` full chunks + the fixed-size masked
    ``tail`` directly (its "plan" is whatever the per-tick packer
    allocates)."""
    req: Request
    plan: List[PlanStep]
    buf: int
    plan_pos: int = 0
    prompt_pos: int = 0
    ready: bool = False
    chunks_left: int = 0      # batched path: full C-chunks not yet staged
    tail: int = 0             # batched path: valid tokens in the admit chunk
    admitted: bool = False    # batched path: admit dispatched, token pending


class Scheduler:
    """Continuous-batching decode scheduler over a ``DeviceExecutor``."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int = 4,
                 max_len: int = 256, seed: int = 0, decode_block: int = 1,
                 overlap: bool = True, prefill_chunk: int = 16,
                 budget_ticks: bool = True, mesh=None,
                 staging_depth: int = 2, plan_mode: str = "masked",
                 prefill_batching: Optional[bool] = None,
                 prefill_budget: Optional[int] = None):
        if decode_block < 1:
            raise ValueError(f"decode_block must be >= 1, got {decode_block}")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(f"prefill_budget must be >= 1 token, got "
                             f"{prefill_budget}")
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.seed = seed
        self.decode_block = decode_block
        self.overlap = overlap
        self.budget_ticks = budget_ticks
        self.executor = DeviceExecutor(
            cfg, params, max_slots=max_slots, max_len=max_len,
            decode_block=decode_block, prefill_chunk=prefill_chunk,
            mesh=mesh, staging_depth=staging_depth, plan_mode=plan_mode,
            prefill_batching=prefill_batching)
        # per-tick prefill token budget of the batched packer, in
        # scan-chunk units (an admit dispatch costs one unit).  The
        # default lets every staging row take a full scan + admit per
        # tick — the batched path is then never slower than the
        # per-prompt one-chunk-per-entry loop it replaces.
        C = self.executor.prefill_chunk
        from repro.serving.executor import _MAX_SCAN_CHUNKS
        self.prefill_budget = prefill_budget
        self._budget_chunks = (
            max(1, prefill_budget // C) if prefill_budget is not None
            else self.executor.staging_depth * (_MAX_SCAN_CHUNKS + 1))
        self._max_scan_chunks = _MAX_SCAN_CHUNKS
        self.free: Deque[int] = deque(range(max_slots))
        self.active: Dict[int, Request] = {}
        self.queue: Deque[Request] = deque()
        self._all: List[Request] = []
        # staging state machine: FIFO of in-flight staged prefills, one per
        # executor ring buffer (free ring indices in _free_bufs); batched
        # rows whose request finished at admit wait in _dirty_rows until a
        # multi-row scatter release-zeroes them
        self._stagings: List[_Staging] = []
        self._free_bufs: Deque[int] = deque(range(self.staging_depth))
        self._dirty_rows: set = set()
        self.ticks = 0
        self.decode_s = 0.0         # wall time inside decode ticks (+ sync)
        self.decoded_tokens = 0     # tokens emitted by ticks (not admit)
        self.stage_dispatches = 0   # prefill-chunk programs dispatched
        self.scatter_dispatches = 0  # slot-scatter programs dispatched
        self._metrics_from = 0      # _all watermark set by reset_metrics

    # ---------------------------------------------------- compat surface
    @property
    def spec(self):
        return self.executor.spec

    @property
    def prefill_chunk(self) -> int:
        return self.executor.prefill_chunk

    @property
    def plan_mode(self) -> str:
        return self.executor.plan_mode

    @property
    def prefill_batching(self) -> bool:
        return self.executor.prefill_batching

    @property
    def staging_depth(self) -> int:
        return self.executor.staging_depth

    @property
    def mesh(self):
        return self.executor.mesh

    @property
    def state_bytes_per_slot(self) -> int:
        return self.executor.state_bytes_per_slot

    @property
    def window_bytes_per_slot(self) -> int:
        return self.executor.window_bytes_per_slot

    @property
    def cache_bytes(self) -> int:
        return self.executor.cache_bytes

    @property
    def caches(self):
        return self.executor.caches

    @property
    def tokens(self):
        return self.executor.tokens

    @property
    def sampler(self):
        return self.executor.sampler

    @property
    def _staging(self) -> Optional[Request]:
        """Head-of-line staged request (back-compat view of the ring)."""
        return self._stagings[0].req if self._stagings else None

    # ------------------------------------------------------------ submit
    def submit(self, req: Request):
        # reject out-of-range sampling params up front: past this point the
        # host mirror and the device pipeline must behave identically
        if not 0.0 < req.top_p <= 1.0:
            raise ValueError(f"req {req.rid}: top_p must be in (0, 1], "
                             f"got {req.top_p}")
        if req.top_k < 0:
            raise ValueError(f"req {req.rid}: top_k must be >= 0, "
                             f"got {req.top_k}")
        if req.temperature <= 0.0 and (req.top_k > 0 or req.top_p < 1.0):
            raise ValueError(f"req {req.rid}: top_k/top_p have no effect "
                             f"at temperature<=0 (greedy); set "
                             f"temperature > 0")
        if req.max_new_tokens < 1:
            raise ValueError(f"req {req.rid}: max_new_tokens must be >= 1 "
                             f"(admit always emits the first token), got "
                             f"{req.max_new_tokens}")
        T = req.prompt_len
        if T is None:
            raise ValueError(f"req {req.rid}: needs a prompt or "
                             f"prompt_embeds")
        if T < 1:
            raise ValueError(f"req {req.rid}: empty prompt")
        if T > self.max_len:
            raise ValueError(
                f"req {req.rid}: prompt length {T} exceeds max_len "
                f"{self.max_len} — the window caches would wrap "
                f"mid-prompt and silently corrupt the context")
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        self._all.append(req)

    def withdraw(self, *, oldest: bool = False) -> Optional[Request]:
        """Remove and return a queued (not yet staging) request, or None.
        Used by the router to move backlog across engines: rebalance
        steals the *newest* (default — the head of the queue keeps its
        FIFO TTFT), drain migrates *oldest*-first so arrival order
        survives the full-queue move."""
        if not self.queue:
            return None
        req = self.queue.popleft() if oldest else self.queue.pop()
        # identity removal (Request is a dataclass; two equal-field
        # requests must not alias), keeping the reset_metrics watermark
        # pointed at the same element
        idx = next(i for i, r in enumerate(self._all) if r is req)
        del self._all[idx]
        if idx < self._metrics_from:
            self._metrics_from -= 1
        return req

    def readmit(self, req: Request):
        """Put a withdrawn request back at the queue tail (router's undo
        when no other engine can accept it); t_submit is preserved."""
        self.queue.append(req)
        self._all.append(req)

    @property
    def load(self) -> int:
        """Requests this engine still owes work to (router placement)."""
        return len(self.active) + len(self.queue) + len(self._stagings)

    def _finished(self, req: Request, tok: int) -> bool:
        return (len(req.output) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id))

    # ----------------------------------------------------------- staging
    def _stage_start(self, req: Request):
        buf = self._free_bufs.popleft()
        if self.executor.prefill_batching:
            # batched path: no fixed plan — the per-tick packer allocates
            # chunks; begin is host-only (rows are release-zeroed by the
            # multi-row scatter, so starting a staging costs no dispatch)
            T = req.prompt_len
            C = self.executor.prefill_chunk
            tail = (T - 1) % C + 1
            self._stagings.append(_Staging(
                req=req, plan=[], buf=buf,
                chunks_left=(T - tail) // C, tail=tail))
            self.executor.bstage_begin(
                buf, seed=self.seed, rid=req.rid,
                temperature=req.temperature, top_k=req.top_k,
                top_p=req.top_p, eos_id=req.eos_id,
                budget=req.max_new_tokens)
            return
        self._stagings.append(_Staging(
            req=req, plan=self.executor.plan_prefill(req.prompt_len),
            buf=buf))
        self.executor.stage_begin(
            buf, seed=self.seed, rid=req.rid, temperature=req.temperature,
            top_k=req.top_k, top_p=req.top_p, eos_id=req.eos_id,
            budget=req.max_new_tokens)

    def _stage_dispatch_one(self, st: _Staging):
        step = st.plan[st.plan_pos]
        chunk = st.req._inputs[st.prompt_pos:st.prompt_pos + step.tokens]
        if step.kind == "scan":
            self.executor.stage_chunk_scan(st.buf, chunk,
                                           valid_lens=step.valid)
        elif step.kind == "chunk":
            self.executor.stage_chunk(st.buf, chunk)
        else:
            self.executor.stage_admit(st.buf, chunk, valid_len=step.valid)
        st.prompt_pos += step.tokens
        st.plan_pos += 1
        self.stage_dispatches += 1

    def _stage_finish(self, st: _Staging):
        """Plan complete: sync the fused first token (this is the
        device-confirmed admit — TTFT is stamped here, not when the
        dispatch was queued) and either complete the request (EOS /
        max_new_tokens=1, never occupying a slot) or hold it staged-ready
        until a slot frees."""
        req = st.req
        tok = int(np.asarray(self.executor.staging_tok[st.buf])[0])
        req.t_first = time.perf_counter()
        req.output.append(tok)
        if self._finished(req, tok):
            req.done = True
            req.t_done = req.t_first
            self._stagings.remove(st)
            self._free_bufs.append(st.buf)
            return
        st.ready = True

    def _stage_scatter(self):
        st = self._stagings.pop(0)
        slot = self.free.popleft()
        self.executor.scatter(slot, st.buf)
        self.scatter_dispatches += 1
        self._free_bufs.append(st.buf)
        self.active[slot] = st.req

    # --------------------------------------------------- batched staging
    def _flush_scatter(self, assigns):
        """One multi-row scatter covering every slot assignment plus the
        dirty (finished-at-admit) rows; released rows return to the free
        pool clean."""
        rows = [row for _, row in assigns]
        self.executor.bscatter(assigns, self._dirty_rows)
        self.scatter_dispatches += 1
        for row in rows:
            self._free_bufs.append(row)
        for row in self._dirty_rows:
            self._free_bufs.append(row)
        self._dirty_rows.clear()

    def _stage_finish_batch(self, sts: List[_Staging]):
        """Every request admitted by one batched dispatch syncs its first
        token from the SAME device-confirmed read and stamps the SAME
        ``t_first`` — a batch admit is one device event, so serial
        per-entry stamps would skew TTFT for all but the first row."""
        toks = np.asarray(self.executor.btoks)      # the one host sync
        now = time.perf_counter()
        for st in sts:
            req = st.req
            tok = int(toks[st.buf])
            req.t_first = now
            req.output.append(tok)
            if self._finished(req, tok):
                req.done = True
                req.t_done = now
                self._stagings.remove(st)
                self._dirty_rows.add(st.buf)    # zeroed at next scatter
            else:
                st.ready = True

    def _dispatch_batched(self, budget: int) -> bool:
        """One packed prefill round: walk the staging FIFO oldest-first,
        allocating each entry up to ``budget`` scan-chunk units (an admit
        costs one unit), then fuse all allocations into at most one
        batched scan + one batched admit dispatch per input kind.  The
        walk never skips past an unfinished older entry once the budget
        runs out — head-of-line (oldest-first) allocation is the
        fairness guard: a long staged prompt always drains at full rate,
        so its dispatch count is bounded by its own chunk count no matter
        how many short prompts arrive behind it.  Interior chunks are
        C-quantized (masks cover only tails and placeholder rows), so
        each prompt's chunk decomposition — and therefore its token
        stream — is bitwise that of per-prompt dispatch."""
        scan_e: Dict[bool, list] = {}
        admit_e: Dict[bool, list] = {}
        admitted: List[_Staging] = []
        for st in self._stagings:
            if st.ready or st.admitted:
                continue
            if budget <= 0:
                break               # strict oldest-first: no skip-ahead
            is_embeds = st.req.prompt is None
            if st.chunks_left:
                take = min(st.chunks_left, self._max_scan_chunks, budget)
                C = self.executor.prefill_chunk
                chunk = st.req._inputs[st.prompt_pos:
                                       st.prompt_pos + take * C]
                scan_e.setdefault(is_embeds, []).append(
                    (st.buf, chunk, take))
                st.prompt_pos += take * C
                st.chunks_left -= take
                budget -= take
            if st.chunks_left == 0 and budget > 0:
                chunk = st.req._inputs[st.prompt_pos:
                                       st.prompt_pos + st.tail]
                admit_e.setdefault(is_embeds, []).append(
                    (st.buf, chunk, st.tail))
                st.prompt_pos += st.tail
                st.admitted = True
                admitted.append(st)
                budget -= 1
        for entries in scan_e.values():
            self.executor.bstage_chunk_scan(entries)
            self.stage_dispatches += 1
        for entries in admit_e.values():
            self.executor.bstage_admit(entries)
            self.stage_dispatches += 1
        if admitted:
            self._stage_finish_batch(admitted)
        return bool(scan_e or admit_e)

    def _admit_batched(self):
        """Batched admit pipeline: per tick, at most ONE multi-row
        scatter, then new stagings (host-only), then one packed prefill
        round of at most one batched scan + one batched admit dispatch
        per input kind — dispatches per tick are O(1) in queue depth.
        While slots are free the loop drains work-conservingly (same
        admits as the serialized baseline); under saturation one round
        per tick keeps the resident slots decoding between prefill
        programs."""
        while True:
            progressed = False
            # multi-row scatter: every head-run staged-ready request takes
            # a free slot in one dispatch (FIFO order preserved)
            assigns = []
            while self._stagings and self._stagings[0].ready and self.free:
                st = self._stagings.pop(0)
                slot = self.free.popleft()
                assigns.append((slot, st.buf))
                self.active[slot] = st.req
            if assigns:
                self._flush_scatter(assigns)
                progressed = True
            # start staging while rows allow; a dirty row blocks a start
            # only until a release-only scatter cleans it
            while (self.queue and (self.free or self.overlap)):
                if not self._free_bufs:
                    if self._dirty_rows:
                        self._flush_scatter([])
                        progressed = True
                        continue
                    break
                self._stage_start(self.queue.popleft())
                progressed = True
            # one packed prefill round; infinite budget while a slot is
            # free (work-conserving parity with the serialized baseline)
            budget = (self._budget_chunks if not self.free
                      else 1 << 30)
            if self._dispatch_batched(budget):
                progressed = True
            if not self.free and self.active:
                return              # saturated: one round per tick
            if not progressed:
                return

    def _admit(self):
        """Advance the admit pipeline at a tick boundary.

        Work-conserving: while free slots exist, queued requests prefill
        and scatter exactly as the serialized baseline does.  The overlap
        is purely additive — when every slot is busy, up to
        ``staging_depth`` head-of-queue requests *still* stream their
        chunk plans into the staging ring, **one chunk dispatch per
        staged request per tick** so the resident slots keep decoding
        between chunks, and emit their fused-sample first tokens at plan
        completion, held staged-ready until slots free (scattered in FIFO
        order).  Overlapped TTFT is therefore never structurally worse
        than serialized, and strictly better whenever a request would
        have had to wait for a slot before prefilling.

        With ``prefill_batching`` (the default when every mixer kind
        supports it) the per-entry loop is replaced by
        ``_admit_batched``: all staged prompts fuse into one batched
        program per dispatch and dispatches per tick are O(1) in queue
        depth."""
        if self.executor.prefill_batching:
            return self._admit_batched()
        yielded = set()     # stagings that already dispatched this tick
        while True:
            # FIFO scatter: the head staged-ready request takes the slot
            if self._stagings and self._stagings[0].ready:
                if self.free:
                    self._stage_scatter()
                    continue    # next queued request may start staging
            # start staging while ring buffers allow (serialized admit
            # waits for a free slot up front)
            if (self.queue and self._free_bufs
                    and (self.free or self.overlap)):
                self._stage_start(self.queue.popleft())
                continue
            st = next((s for s in self._stagings
                       if not s.ready and id(s) not in yielded), None)
            if st is None:
                return
            self._stage_dispatch_one(st)
            if st.plan_pos == len(st.plan):
                self._stage_finish(st)
            elif not self.free and self.active:
                yielded.add(id(st))     # ahead-of-slot: one chunk per tick
                                        # so the resident slots decode
                                        # between prefill chunks

    # -------------------------------------------------------------- tick
    def _tick_k(self) -> int:
        """Budget-aware tick length: smallest power-of-two bucket (capped
        at ``decode_block``) covering the largest remaining per-slot
        budget — the all-slots-finish-early tail stops burning masked
        scan steps, and bucketing bounds the program cache."""
        if not self.budget_ticks:
            return self.decode_block
        need = max(r.max_new_tokens - len(r.output)
                   for r in self.active.values())
        k = 1
        while k < need and k < self.decode_block:
            k <<= 1
        return min(k, self.decode_block)

    def step(self):
        """One engine tick: advance the admit pipeline (free slots fill as
        in the serialized baseline, plus up to ``staging_depth``
        ahead-of-slot staged prefills when every slot is busy), then one
        fused decode+sample scan, then emit and free — a single host sync
        for the decode block."""
        self._admit()
        if not self.active:
            return
        k = self._tick_k()
        t0 = time.perf_counter()
        toks, valid = self.executor.decode(k)   # (k, S) — the one host sync
        now = time.perf_counter()
        self.decode_s += now - t0
        self.ticks += 1
        for slot, req in list(self.active.items()):
            for j in range(toks.shape[0]):
                if not valid[j, slot]:
                    break
                tok = int(toks[j, slot])
                req.output.append(tok)
                self.decoded_tokens += 1
                if self._finished(req, tok):
                    req.done = True
                    req.t_done = now
                    del self.active[slot]
                    self.free.append(slot)
                    break

    def run_until_done(self, max_ticks: int = 10_000, *,
                       strict: bool = True) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active and not self._stagings:
                break
            self.step()
        if self.queue or self.active or self._stagings:
            msg = (f"run_until_done: max_ticks={max_ticks} exhausted with "
                   f"{len(self.queue)} queued, {len(self.active)} active, "
                   f"{len(self._stagings)} staging request(s) "
                   f"unfinished — raise max_ticks or inspect the engine")
            if strict:
                raise RuntimeError(msg)
            warnings.warn(msg, RuntimeWarning)
        return [r for r in self._all if r.done]

    # ----------------------------------------------------------- metrics
    def reset_metrics(self):
        """Zero the aggregate counters (benchmarks call this after a
        warm-up pass so compile time stays out of the measurement)."""
        self.ticks = 0
        self.decode_s = 0.0
        self.decoded_tokens = 0
        self.stage_dispatches = 0
        self.scatter_dispatches = 0
        self._metrics_from = len(self._all)

    def metrics(self) -> Dict[str, float]:
        """Aggregate serving metrics over requests completed since the
        last ``reset_metrics`` (all requests by default)."""
        done = [r for r in self._all[self._metrics_from:] if r.done]
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        lats = [r.latency_s for r in done if r.latency_s is not None]
        tps = [r.tokens_per_s for r in done if r.tokens_per_s is not None]
        mesh = self.executor.mesh
        progs = self.executor.compiled_programs()
        return {
            "requests": len(done),
            "tokens": sum(len(r.output) for r in done),
            "ticks": self.ticks,
            "decode_block": self.decode_block,
            "decoded_tokens": self.decoded_tokens,
            "decode_s": self.decode_s,
            "decode_us_per_token":
                self.decode_s / max(1, self.decoded_tokens) * 1e6,
            "stage_dispatches": self.stage_dispatches,
            "scatter_dispatches": self.scatter_dispatches,
            "overlap": int(self.overlap),
            "prefill_chunk": self.executor.prefill_chunk,
            "plan_mode": self.executor.plan_mode,
            "prefill_batching": int(self.executor.prefill_batching),
            "compiled_programs": progs["total"],
            "prefill_programs": progs["prefill"],
            "staging_depth": self.staging_depth,
            "mesh_data": int(mesh.shape["data"]) if mesh is not None else 1,
            "mesh_model": (int(mesh.shape["model"])
                           if mesh is not None else 1),
            "mean_ttft_s": float(np.mean(ttfts)) if ttfts else 0.0,
            "mean_latency_s": float(np.mean(lats)) if lats else 0.0,
            "mean_tokens_per_s": float(np.mean(tps)) if tps else 0.0,
        }
