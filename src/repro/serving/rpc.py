"""Process-boundary serving engines: ``EngineWorker`` + ``EngineProxy``.

The ``Router`` scales serving across engines, but in-process engines
still share one Python interpreter: a prefill storm on engine 0 steals
wall-clock from engine 1's decode ticks (the GIL and the single
dispatch thread serialize them).  This module puts each engine in its
own **worker process** — one ``Scheduler`` per process, each owning its
own jax runtime — and fronts it with an ``EngineProxy`` that speaks the
full engine surface the router uses, over a length-prefixed frame
protocol (``repro.serving.wire``) on the worker's stdin/stdout pipes.

Protocol (all frames are ``wire``-encoded):

  * proxy → worker: one **init** frame (arch config, params seed or
    host-materialized params, engine kwargs, optional mesh shape), then
    a stream of ``[op, payload]`` frames;
  * worker → proxy: one reply per frame —
    ``{"ok", "result", "updates", "status"}``.  ``updates`` streams the
    mutable-progress slice of every live request (output tokens, state,
    timing stamps) so the **caller's own ``Request`` objects stay
    live** — the proxy keeps a mirror of every submitted request and
    applies updates to the original objects, exactly like an in-process
    engine mutating them.  ``status`` snapshots the narrow surface the
    router reads between calls (``load``, ``free_slots``, ``handoffs``,
    …) so reading a proxy property never blocks on a round trip.

Pipelined stepping: ``step_begin`` issues a tick without waiting and
``step_drain(block=...)`` collects the reply when it lands — at most
one step is ever in flight, every other op flushes it first.  The
router uses this to let a decode worker tick at its own pace while a
prefill worker chews a long prompt (the disaggregation win: two
processes really do run concurrently).

Worker death: EOF / broken pipe on the channel raises ``WorkerDied``;
the proxy marks itself dead and ``recover_queued`` hands back the
still-queued mirror requests (re-homeable — their prompts live in the
caller) and marks requests whose state lived in the dead process as
``"failed"``.

Weights cross the boundary as a **seed** when possible
(``params_seed`` → the worker rebuilds ``lm.init_lm(PRNGKey(seed),
cfg)``, deterministic across processes) and as host numpy otherwise.
No timeouts are imposed on replies — a first step may sit behind
minutes of XLA compilation; death is detected by EOF, not silence.
"""
from __future__ import annotations

import selectors
import subprocess
import sys
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving import wire

# ops the worker understands; everything the Router touches on an engine
_OPS = ("submit", "step", "pause", "resume", "touch", "withdraw",
        "readmit", "withdraw_swapped", "readmit_swapped",
        "withdraw_handoff", "flush_swaps", "metrics", "reset_metrics",
        "shutdown")

_EXC: Dict[str, type] = {
    "ValueError": ValueError, "KeyError": KeyError,
    "IndexError": IndexError, "TypeError": TypeError,
    "RuntimeError": RuntimeError,
}


class WorkerDied(RuntimeError):
    """The engine worker process is gone (EOF/broken pipe mid-call)."""


def _hostify(tree):
    """Materialize a (possibly device-resident) pytree as host numpy so
    the wire codec frames every leaf bitwise instead of pickling it."""
    import jax
    return jax.tree.map(np.asarray, jax.device_get(tree))


# ======================================================================
# worker side
# ======================================================================
def _status(eng) -> Dict[str, Any]:
    return {
        "load": eng.load,
        "queue_len": eng.queue_len,
        "free_slots": eng.free_slots,
        "staging_len": eng.staging_len,
        "resume_len": eng.resume_len,
        "idle_capacity": eng.idle_capacity,
        "handoffs": eng.handoffs,
    }


class EngineWorker:
    """Hosts one ``Scheduler`` and serves the frame protocol on a pair
    of binary streams.  Run as ``python -m repro.serving.rpc`` (stdin /
    stdout pipes — stdout is reserved for frames; anything the engine
    prints goes to stderr)."""

    def __init__(self, inp, out):
        self.inp = inp
        self.out = out
        self.eng = None
        self.reqs: Dict[int, Any] = {}      # rid -> live worker-side Request

    # ------------------------------------------------------------ setup
    def _build(self, init: Dict[str, Any]):
        import jax
        from repro.serving.scheduler import Scheduler

        cfg = init["cfg"]
        if init.get("params_seed") is not None:
            from repro.models import lm
            params = lm.init_lm(jax.random.PRNGKey(init["params_seed"]),
                                cfg)
        else:
            params = init["params"]
        kwargs = dict(init.get("kwargs") or {})
        mesh_shape = init.get("mesh_shape")
        if mesh_shape is not None:
            axes = tuple(init.get("mesh_axes") or ("data", "model"))
            kwargs["mesh"] = jax.make_mesh(tuple(mesh_shape), axes)
        self.eng = Scheduler(cfg, params, **kwargs)
        return {"max_len": self.eng.max_len, "role": self.eng.role,
                "max_slots": self.eng.max_slots}

    # --------------------------------------------------------- dispatch
    def _dispatch(self, op: str, payload) -> Any:
        eng = self.eng
        if op == "submit":
            req = wire.decode_request(payload)
            eng.submit(req)
            self.reqs[req.rid] = req
            return None
        if op == "step":
            eng.step()
            return None
        if op == "pause":
            eng.pause(payload)
            return None
        if op == "resume":
            eng.resume(payload)
            return None
        if op == "touch":
            eng.touch(payload)
            return None
        if op == "withdraw":
            req = eng.withdraw(oldest=bool(payload))
            if req is None:
                return None
            self.reqs.pop(req.rid, None)
            return wire.request_update(req)
        if op == "readmit":
            req = wire.decode_request(payload)
            eng.readmit(req)
            self.reqs[req.rid] = req
            return None
        if op in ("withdraw_swapped", "withdraw_handoff"):
            rec = (eng.withdraw_swapped() if op == "withdraw_swapped"
                   else eng.withdraw_handoff())
            if rec is None:
                return None
            self.reqs.pop(rec.req.rid, None)
            return wire.encode_swap_record(rec)
        if op == "readmit_swapped":
            rec = wire.decode_swap_record(payload)
            eng.readmit_swapped(rec)
            self.reqs[rec.req.rid] = rec.req
            return None
        if op == "flush_swaps":
            eng.flush_swaps()
            return None
        if op == "metrics":
            return eng.metrics()
        if op == "reset_metrics":
            eng.reset_metrics()
            return None
        if op == "shutdown":
            return None
        raise ValueError(f"rpc: unknown op {op!r}")

    def _updates(self) -> List[Dict[str, Any]]:
        ups = []
        for rid, req in list(self.reqs.items()):
            ups.append(wire.request_update(req))
            if req.done:        # final update sent — the proxy's mirror
                del self.reqs[rid]      # keeps the finished object
        return ups

    def _reply(self, ok: bool, result=None, err: Optional[Tuple] = None):
        msg = {"ok": ok, "result": result,
               "updates": self._updates() if self.eng is not None else [],
               "status": _status(self.eng) if self.eng is not None
               else None}
        if err is not None:
            msg["err"], msg["msg"] = err
        wire.write_frame(self.out, wire.encode(msg))

    # ------------------------------------------------------------- loop
    def serve(self) -> int:
        try:
            init = wire.decode(wire.read_frame(self.inp))
        except EOFError:
            return 0
        try:
            info = self._build(init)
        except Exception as e:          # init failure is fatal
            self._reply(False, err=(type(e).__name__, str(e)))
            return 1
        self._reply(True, result=info)
        while True:
            try:
                frame = wire.read_frame(self.inp)
            except EOFError:            # proxy closed the pipe: done
                return 0
            op, payload = wire.decode(frame)
            try:
                result = self._dispatch(op, payload)
            except Exception as e:
                self._reply(False, err=(type(e).__name__, str(e)))
            else:
                self._reply(True, result=result)
            if op == "shutdown":
                return 0


# ======================================================================
# proxy side
# ======================================================================
class EngineProxy:
    """Router-facing handle on an ``EngineWorker`` subprocess.  Speaks
    the in-process engine surface: ``submit``/``step``/``pause``/
    ``resume``/``touch``/``withdraw*``/``readmit*``/``metrics``/… plus
    the pipelined ``step_begin``/``step_drain`` pair the router uses to
    tick workers concurrently.  Constructor args mirror ``Scheduler``
    — pass ``params_seed`` instead of params when the weights are a
    deterministic init (cheap to ship, bitwise-identical on rebuild)."""

    def __init__(self, cfg, params=None, *, params_seed: Optional[int] = None,
                 mesh_shape=None, mesh_axes=None,
                 python: str = sys.executable, **engine_kwargs):
        if (params is None) == (params_seed is None):
            raise ValueError("EngineProxy: pass exactly one of params / "
                             "params_seed")
        self.cfg = cfg
        self.role = engine_kwargs.get("role", "both")
        self.dead = False
        self._reqs: Dict[int, Any] = {}     # mirror: rid -> caller's Request
        self._status: Dict[str, Any] = {
            "load": 0, "queue_len": 0, "free_slots": 0, "staging_len": 0,
            "resume_len": 0, "idle_capacity": 0, "handoffs": 0}
        self._inflight_step = False
        if "draft_params" in engine_kwargs \
                and engine_kwargs["draft_params"] is not None:
            engine_kwargs["draft_params"] = _hostify(
                engine_kwargs["draft_params"])
        self.proc = subprocess.Popen(
            [python, "-m", "repro.serving.rpc"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE)
        self._sel = selectors.DefaultSelector()
        self._sel.register(self.proc.stdout, selectors.EVENT_READ)
        init = {"cfg": cfg,
                "params": None if params is None else _hostify(params),
                "params_seed": params_seed,
                "kwargs": engine_kwargs,
                "mesh_shape": (tuple(mesh_shape)
                               if mesh_shape is not None else None),
                "mesh_axes": (tuple(mesh_axes)
                              if mesh_axes is not None else None)}
        self._write(wire.encode(init))
        info = self._read_reply()           # blocks through engine build
        self.max_len = info["max_len"]
        self.max_slots = info["max_slots"]
        self.role = info["role"]

    # ---------------------------------------------------------- channel
    def _write(self, payload: bytes):
        try:
            wire.write_frame(self.proc.stdin, payload)
        except (BrokenPipeError, OSError) as e:
            self._die(e)

    def _read_reply(self):
        try:
            reply = wire.decode(wire.read_frame(self.proc.stdout))
        except (EOFError, OSError) as e:
            self._die(e)
        if reply.get("status") is not None:
            self._status = reply["status"]
        for u in reply.get("updates") or ():
            req = self._reqs.get(u["rid"])
            if req is not None:
                wire.apply_request_update(req, u)
        if not reply["ok"]:
            exc = _EXC.get(reply.get("err", ""), RuntimeError)
            raise exc(f"[worker] {reply.get('msg', '')}")
        return reply["result"]

    def _die(self, cause) -> "NoReturn":
        self.dead = True
        self._inflight_step = False
        try:
            self.proc.kill()
        except OSError:
            pass
        raise WorkerDied(f"engine worker pid {self.proc.pid} died: "
                         f"{cause}") from cause

    def _call(self, op: str, payload=None):
        if self.dead:
            raise WorkerDied(f"engine worker pid {self.proc.pid} is dead")
        self.step_drain(block=True)         # at most one frame in flight
        self._write(wire.encode([op, payload]))
        return self._read_reply()

    # ------------------------------------------------- pipelined ticking
    def step_begin(self):
        """Issue one tick without waiting for it.  No-op if a tick is
        already in flight — the worker paces itself."""
        if self.dead:
            raise WorkerDied(f"engine worker pid {self.proc.pid} is dead")
        if self._inflight_step:
            return
        self._write(wire.encode(["step", None]))
        self._inflight_step = True

    def step_drain(self, *, block: bool) -> bool:
        """Collect the in-flight tick's reply if there is one.  With
        ``block=False`` returns False when the worker hasn't answered
        yet; with ``block=True`` waits for it.  Returns True if a reply
        was consumed."""
        if not self._inflight_step:
            return False
        if not block and not self._sel.select(timeout=0):
            return False
        self._inflight_step = False
        self._read_reply()
        return True

    def step(self):
        self.step_begin()
        self.step_drain(block=True)

    # ------------------------------------------------------- engine surface
    def submit(self, req):
        self._reqs[req.rid] = req
        try:
            self._call("submit", wire.encode_request(req))
        except Exception:
            if not req.done and req.state in ("new", "failed"):
                self._reqs.pop(req.rid, None)
            raise

    def withdraw(self, *, oldest: bool = False):
        u = self._call("withdraw", oldest)
        if u is None:
            return None
        req = self._reqs.pop(u["rid"])
        wire.apply_request_update(req, u)
        return req

    def readmit(self, req):
        self._reqs[req.rid] = req
        self._call("readmit", wire.encode_request(req))

    def pause(self, rid: int):
        self._call("pause", rid)
        return self._reqs[rid]

    def resume(self, rid: int):
        self._call("resume", rid)
        return self._reqs[rid]

    def touch(self, rid: int):
        self._call("touch", rid)

    def _withdraw_record(self, op: str):
        raw = self._call(op)
        if raw is None:
            return None
        rec = wire.decode_swap_record(raw)
        # hand back the CALLER'S request object, not the wire copy: the
        # router re-homes records between engines while clients keep
        # polling the object they submitted
        mine = self._reqs.pop(rec.req.rid, None)
        if mine is not None:
            wire.apply_request_update(mine, wire.request_update(rec.req))
            rec.req = mine
        return rec

    def withdraw_swapped(self):
        return self._withdraw_record("withdraw_swapped")

    def withdraw_handoff(self):
        return self._withdraw_record("withdraw_handoff")

    def readmit_swapped(self, rec):
        self._reqs[rec.req.rid] = rec.req
        self._call("readmit_swapped", wire.encode_swap_record(rec))

    def flush_swaps(self):
        self._call("flush_swaps")

    def metrics(self) -> Dict[str, Any]:
        return self._call("metrics")

    def reset_metrics(self):
        self._call("reset_metrics")

    # ------------------------------------------------- router narrow surface
    @property
    def load(self) -> int:
        return self._status["load"]

    @property
    def queue_len(self) -> int:
        return self._status["queue_len"]

    @property
    def free_slots(self) -> int:
        return self._status["free_slots"]

    @property
    def staging_len(self) -> int:
        return self._status["staging_len"]

    @property
    def resume_len(self) -> int:
        return self._status["resume_len"]

    @property
    def idle_capacity(self) -> int:
        return self._status["idle_capacity"]

    @property
    def handoffs(self) -> int:
        return self._status["handoffs"]

    def owns(self, rid: int) -> bool:
        req = self._reqs.get(rid)
        return req is not None and not req.done

    def done_requests(self):
        return [r for r in self._reqs.values() if r.done]

    # ---------------------------------------------------- death recovery
    def recover_queued(self):
        """After the worker died: split the mirror into requests that
        never left the queue (returned for re-homing — their prompts
        live caller-side) and requests whose device/host state died with
        the process (marked ``"failed"``)."""
        queued, lost = [], []
        for req in self._reqs.values():
            if req.done:
                continue
            if req.state in ("new", "queued"):
                queued.append(req)
            else:
                req.state = "failed"
                lost.append(req)
        for req in queued:      # re-homed requests leave this mirror so
            self._reqs.pop(req.rid, None)   # only the new owner reports
        return queued, lost                 # them via done_requests()

    # ----------------------------------------------------------- teardown
    def shutdown(self):
        """Graceful stop: drain any in-flight tick, send shutdown, reap
        the process.  Safe to call twice / after death."""
        if not self.dead:
            try:
                self._call("shutdown")
            except WorkerDied:
                pass
        self.dead = True
        for stream in (self.proc.stdin, self.proc.stdout):
            try:
                stream.close()
            except OSError:
                pass
        try:
            self.proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait()

    close = shutdown

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.shutdown()

    def __del__(self):
        try:
            if self.proc.poll() is None:
                self.proc.kill()
        except Exception:
            pass


def main() -> int:
    # stdout carries frames; rebind print()-style output to stderr so a
    # stray print inside jax/engine code can't corrupt the protocol
    out = sys.stdout.buffer
    sys.stdout = sys.stderr
    return EngineWorker(sys.stdin.buffer, out).serve()


if __name__ == "__main__":
    sys.exit(main())
