"""Wire codec for the serving stack's host-boundary images.

One serializer for every path that moves a request's state across a
process or storage boundary:

  * the RPC protocol between a ``Router``-side ``EngineProxy`` and its
    ``EngineWorker`` subprocess (``repro.serving.rpc``) — submits,
    swapped-image migrations and prefill→decode handoffs all ship
    through ``encode``/``decode``;
  * the spill-to-disk spool tier of async state paging
    (``Scheduler._spill`` / ``_load_spill``) — the on-disk image is the
    same bytes the RPC path would send, so a spooled session could in
    principle be reloaded by any compatible engine, local or remote.

The format is a tiny tagged binary encoding (length-prefixed fields, no
schema negotiation — both ends are this codebase).  The load-bearing
property is **bitwise round-trip of numpy leaves**: arrays are framed
with ``np.lib.format`` (the ``.npy`` encoding), which preserves dtype,
shape and byte order exactly — a ``SwappedState`` decoded on the far
side restores through the slot scatter bitwise-identically to the
local image (the PR 7 guarantee, extended across the process boundary).
Container structure (the cache pytree's treedef) rides along via
pickle — acceptable because every participant runs the same code; the
arrays themselves are NEVER pickled (``allow_pickle=False``).

Framing: ``write_frame``/``read_frame`` length-prefix each message with
8 big-endian bytes for the pipe/socket protocol.
"""
from __future__ import annotations

import dataclasses
import io
import pickle
import struct
from typing import Any, BinaryIO, Dict

import numpy as np

# field tags — one byte each
_T_NONE = b"N"
_T_TRUE = b"T"
_T_FALSE = b"F"
_T_INT = b"i"
_T_FLOAT = b"f"
_T_STR = b"s"
_T_BYTES = b"b"
_T_LIST = b"l"
_T_TUPLE = b"t"
_T_DICT = b"d"
_T_NDARRAY = b"a"
_T_PICKLE = b"p"        # structure-only fallback (treedefs, configs) —
                        # never used for array payloads

_LEN = struct.Struct(">Q")
_I64 = struct.Struct(">q")
_F64 = struct.Struct(">d")


# ------------------------------------------------------------ encoding
def _enc(out: io.BytesIO, obj: Any):
    if obj is None:
        out.write(_T_NONE)
    elif obj is True:
        out.write(_T_TRUE)
    elif obj is False:
        out.write(_T_FALSE)
    elif isinstance(obj, (int, np.integer)):
        out.write(_T_INT)
        out.write(_I64.pack(int(obj)))
    elif isinstance(obj, (float, np.floating)):
        out.write(_T_FLOAT)
        out.write(_F64.pack(float(obj)))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.write(_T_STR)
        out.write(_LEN.pack(len(raw)))
        out.write(raw)
    elif isinstance(obj, (bytes, bytearray)):
        out.write(_T_BYTES)
        out.write(_LEN.pack(len(obj)))
        out.write(bytes(obj))
    elif isinstance(obj, np.ndarray):
        if obj.dtype == object:
            raise TypeError("wire: refusing to encode an object-dtype "
                            "array (no bitwise representation)")
        out.write(_T_NDARRAY)
        bio = io.BytesIO()
        # NB: np.ascontiguousarray promotes 0-d to 1-d; guard on the
        # flag so scalar arrays keep their shape across the wire.
        arr = obj if obj.flags.c_contiguous else np.ascontiguousarray(obj)
        np.lib.format.write_array(bio, arr, allow_pickle=False)
        raw = bio.getvalue()
        out.write(_LEN.pack(len(raw)))
        out.write(raw)
    elif isinstance(obj, list):
        out.write(_T_LIST)
        out.write(_LEN.pack(len(obj)))
        for x in obj:
            _enc(out, x)
    elif isinstance(obj, tuple):
        out.write(_T_TUPLE)
        out.write(_LEN.pack(len(obj)))
        for x in obj:
            _enc(out, x)
    elif isinstance(obj, dict):
        out.write(_T_DICT)
        out.write(_LEN.pack(len(obj)))
        for k, v in obj.items():
            _enc(out, k)
            _enc(out, v)
    else:
        # structure-only fallback: pytree treedefs, ArchConfig — small,
        # same codebase on both sides of the pipe
        raw = pickle.dumps(obj, protocol=4)
        out.write(_T_PICKLE)
        out.write(_LEN.pack(len(raw)))
        out.write(raw)


def encode(obj: Any) -> bytes:
    """Serialize ``obj`` (numbers, strings, bytes, lists/tuples/dicts,
    numpy arrays — arrays bitwise via the .npy encoding)."""
    out = io.BytesIO()
    _enc(out, obj)
    return out.getvalue()


# ------------------------------------------------------------ decoding
def _read(buf: io.BytesIO, n: int) -> bytes:
    raw = buf.read(n)
    if len(raw) != n:
        raise EOFError(f"wire: truncated field (wanted {n} bytes, got "
                       f"{len(raw)})")
    return raw


def _dec(buf: io.BytesIO) -> Any:
    tag = _read(buf, 1)
    if tag == _T_NONE:
        return None
    if tag == _T_TRUE:
        return True
    if tag == _T_FALSE:
        return False
    if tag == _T_INT:
        return _I64.unpack(_read(buf, 8))[0]
    if tag == _T_FLOAT:
        return _F64.unpack(_read(buf, 8))[0]
    if tag == _T_STR:
        n = _LEN.unpack(_read(buf, 8))[0]
        return _read(buf, n).decode("utf-8")
    if tag == _T_BYTES:
        n = _LEN.unpack(_read(buf, 8))[0]
        return _read(buf, n)
    if tag == _T_NDARRAY:
        n = _LEN.unpack(_read(buf, 8))[0]
        return np.lib.format.read_array(io.BytesIO(_read(buf, n)),
                                        allow_pickle=False)
    if tag == _T_LIST:
        n = _LEN.unpack(_read(buf, 8))[0]
        return [_dec(buf) for _ in range(n)]
    if tag == _T_TUPLE:
        n = _LEN.unpack(_read(buf, 8))[0]
        return tuple(_dec(buf) for _ in range(n))
    if tag == _T_DICT:
        n = _LEN.unpack(_read(buf, 8))[0]
        return {_dec(buf): _dec(buf) for _ in range(n)}
    if tag == _T_PICKLE:
        n = _LEN.unpack(_read(buf, 8))[0]
        return pickle.loads(_read(buf, n))
    raise ValueError(f"wire: unknown tag {tag!r}")


def decode(raw: bytes) -> Any:
    return _dec(io.BytesIO(raw))


# ------------------------------------------------------------- framing
def write_frame(f: BinaryIO, payload: bytes):
    """Length-prefixed frame: 8 big-endian length bytes + payload."""
    f.write(_LEN.pack(len(payload)))
    f.write(payload)
    f.flush()


def read_frame(f: BinaryIO) -> bytes:
    """Read one frame; raises EOFError on a closed/truncated stream
    (the proxy's worker-death signal)."""
    head = f.read(8)
    if len(head) != 8:
        raise EOFError("wire: stream closed mid-header"
                       if head else "wire: stream closed")
    n = _LEN.unpack(head)[0]
    chunks, got = [], 0
    while got < n:
        chunk = f.read(n - got)
        if not chunk:
            raise EOFError(f"wire: stream closed mid-frame "
                           f"({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


# ----------------------------------------------------- SwappedState ⇄ bytes
def _np_tree(tree):
    """Materialize every leaf as host numpy (device_get for jax arrays;
    a no-op for arrays already on host)."""
    import jax
    return jax.tree.map(np.asarray, jax.device_get(tree))


def encode_swapped(sw) -> bytes:
    """``SwappedState`` → bytes: cache leaves + pickled treedef +
    sampler row + last token, every array framed bitwise."""
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(sw.caches)
    return encode({
        "treedef": treedef,
        "leaves": [np.asarray(x) for x in jax.device_get(leaves)],
        "sampler": {k: np.asarray(v)
                    for k, v in _np_tree(sw.sampler).items()},
        "token": np.asarray(jax.device_get(sw.token)),
    })


def decode_swapped(raw: bytes):
    import jax
    from repro.serving.executor import SwappedState
    d = decode(raw)
    caches = jax.tree_util.tree_unflatten(d["treedef"], d["leaves"])
    return SwappedState(caches=caches, sampler=d["sampler"],
                        token=d["token"])


def dump_swapped(path: str, sw):
    """Spool-tier writer: the on-disk spill image is the wire encoding
    (one serializer for RPC and disk — the treedef travels WITH the
    leaves, so nothing about the image stays pinned in host memory)."""
    with open(path, "wb") as f:
        f.write(encode_swapped(sw))


def load_swapped(path: str):
    with open(path, "rb") as f:
        return decode_swapped(f.read())


# ---------------------------------------------------------- Request ⇄ bytes
def encode_request(req) -> bytes:
    """``Request`` → bytes, field-complete: prompt arrays bitwise,
    wall-clock stamps verbatim (``perf_counter`` is CLOCK_MONOTONIC on
    Linux — comparable across processes on one host, so TTFT spans a
    cross-worker handoff correctly)."""
    d = {}
    for f in dataclasses.fields(req):
        v = getattr(req, f.name)
        if isinstance(v, np.ndarray):
            v = np.asarray(v)
        d[f.name] = v
    return encode(d)


def decode_request(raw: bytes):
    from repro.serving.scheduler import Request
    d = decode(raw)
    d["output"] = list(d.get("output") or [])
    return Request(**d)


# ------------------------------------------------------ swap record ⇄ bytes
def encode_swap_record(rec) -> bytes:
    """A scheduler ``_Swapped`` record (request + harvested host image +
    swap stamp) → bytes — the unit the router migrates between engines
    and the prefill→decode handoff ships.  The record must be fully
    harvested (no pending drain / prefetch / spool) — ``withdraw_swapped``
    and ``withdraw_handoff`` guarantee that."""
    if rec.pending is not None or rec.prefetch is not None \
            or rec.spool is not None:
        raise ValueError("wire: swap record must be fully harvested "
                         "before it crosses the process boundary")
    return encode({
        "req": encode_request(rec.req),
        "state": (encode_swapped(rec.state)
                  if rec.state is not None else None),
        "t_swap": rec.t_swap,
    })


def decode_swap_record(raw: bytes):
    from repro.serving.scheduler import _Swapped
    d = decode(raw)
    return _Swapped(
        req=decode_request(d["req"]),
        state=(decode_swapped(d["state"])
               if d["state"] is not None else None),
        t_swap=d["t_swap"])


REQUEST_SYNC_FIELDS = (
    "output", "done", "state", "t_submit", "t_first", "t_done",
    "swapped_s", "_swapped_pre_first_s", "t_last_activity", "_t_active",
)


def request_update(req) -> Dict[str, Any]:
    """The mutable-progress slice of a ``Request`` — what an
    ``EngineWorker`` streams back so the caller's own object (held
    across the process boundary by the proxy's mirror) stays live."""
    u = {"rid": req.rid}
    for k in REQUEST_SYNC_FIELDS:
        v = getattr(req, k)
        u[k] = list(v) if k == "output" else v
    return u


def apply_request_update(req, u: Dict[str, Any]):
    for k in REQUEST_SYNC_FIELDS:
        v = u[k]
        setattr(req, k, list(v) if k == "output" else v)
