"""Device executor: the serving engine's jitted donated-buffer programs.

This is the device half of the scheduler/executor split.  Everything that
touches an accelerator buffer lives here; everything that touches a
``Request`` lives in ``repro.serving.scheduler``.  The executor owns

  * the **slot buffers** — every layer's recurrent state / KV cache with a
    leading slot axis, the per-slot sampler arrays and the per-slot last
    tokens, all donated through every tick so XLA updates them in place
    (the TPU analogue of the paper's BRAM-resident state);
  * the **staging buffers** — a single-sequence cache pytree plus a 1-row
    sampler state that chunked prefill streams into while the resident
    slots keep decoding, scattered into a real slot only once staging
    completes (the serving-layer version of the paper's
    prepare/compute/store overlap);
  * the **programs** — one jitted, donated program per static shape:
    - ``decode(k)``: the ``lm.decode_steps`` fused decode+sample scan, one
      program per bucketed tick length k (budget-aware ticks pick the
      smallest bucket covering the max remaining per-slot budget);
    - ``stage_chunk_scan`` / ``stage_chunk`` / ``stage_admit``: chunked
      prefill into the staging cache — full chunks of ``prefill_chunk``
      tokens run m-at-a-time under one ``lax.scan`` (one program per
      power-of-two m), the ragged tail is decomposed into power-of-two
      sub-chunks (one program per size), and the final sub-chunk fuses the
      first-token draw on device (``lm.prefill_sample``), so admit never
      ships logits to the host;
    - ``scatter(slot)``: one donated ``dynamic_update_slice`` over the
      whole staging pytree + sampler row + first token into slot ``slot``.

  Every program is compiled lazily on first use and cached by its static
  shape, so the compile-cache size is bounded by the bucketing: O(log)
  distinct chunk/scan sizes and O(log) tick lengths.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serving import sampling

PlanStep = Tuple[str, int]   # ("scan", m chunks) | ("chunk"|"admit", s tokens)

# cap on chunks per scan dispatch: a single scan step is one program on the
# tick thread, so unbounded m would stall resident decode slots for nearly
# the whole prompt — bounding it keeps the overlap granular (and shrinks
# the compile cache to scan programs of m in {1, 2, 4})
_MAX_SCAN_CHUNKS = 4


def _pow2_floor(n: int) -> int:
    return 1 << (n.bit_length() - 1)


def _scatter_fn(caches, sampler, tokens, staging, row, tok, slot):
    """Write the staging cache pytree, sampler row and first token into
    slot ``slot``.  Cache leaves are (repeats, slots, ...) vs
    (repeats, 1, ...); ``slot`` is traced so the whole-pytree scatter
    compiles once and runs in place (donated)."""
    caches = jax.tree.map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=1),
        caches, staging)
    sampler = {
        k: jax.lax.dynamic_update_slice_in_dim(
            v, row[k].astype(v.dtype), slot, axis=0)
        for k, v in sampler.items()}
    tokens = jax.lax.dynamic_update_slice(
        tokens, tok.astype(tokens.dtype), (slot,))
    return caches, sampler, tokens


class DeviceExecutor:
    """Owns the device buffers and jitted programs of one decode engine."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int,
                 max_len: int, decode_block: int, prefill_chunk: int = 16):
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_block = decode_block
        # chunks scatter into rolling KV buffers, whose size is
        # min(window, max_len) — one chunk must not wrap a buffer
        limit = min(max_len, cfg.window) if cfg.window else max_len
        self.prefill_chunk = max(1, min(prefill_chunk, limit))

        # spec-driven slot buffers: shapes, dtypes and byte budgets all
        # come from the mixers' declarative cache specs
        self.spec = lm.cache_specs(cfg, max_slots, max_len)
        self.caches = self.spec.zeros()
        slot_spec = lm.cache_specs(cfg, 1, max_len)
        self.state_bytes_per_slot = slot_spec.state_bytes
        self.window_bytes_per_slot = slot_spec.window_bytes
        self.cache_bytes = self.spec.nbytes
        self.tokens = jnp.zeros((max_slots,), jnp.int32)
        self.sampler = sampling.init_state(max_slots)

        # staging buffers (prefill overlap target); the sampler row is
        # produced by the fused admit program, not materialized up front
        self._staging_zeros = jax.jit(lambda: lm.init_caches(cfg, 1, max_len))
        self.staging = self._staging_zeros()
        self._staging_clean = True
        self._staging_args = None
        self.staging_row = None
        self.staging_tok: Optional[jax.Array] = None

        # lazily-built program caches, keyed by static shape
        self._decode_p: Dict[int, object] = {}
        self._scan_p: Dict[Tuple[int, bool], object] = {}
        self._chunk_p: Dict[Tuple[int, bool], object] = {}
        self._admit_p: Dict[Tuple[int, bool], object] = {}
        # donate only the slot buffers: the staging pytree's (repeats, 1,
        # ...) leaves have no same-shape output to alias (XLA would warn)
        self._scatter_p = jax.jit(_scatter_fn, donate_argnums=(0, 1, 2))

    # ------------------------------------------------------------- plans
    def plan_prefill(self, length: int) -> List[PlanStep]:
        """Decompose a prompt of ``length`` tokens into dispatch steps.

        Full ``prefill_chunk``-size chunks run m-at-a-time under the scan
        program, m a power of two capped at ``_MAX_SCAN_CHUNKS`` (each
        program is compiled once ever, and no single dispatch holds the
        tick thread for more than that many chunks); the ragged tail
        (always >= 1 token, so the final logits always come from a tail
        step) is decomposed into power-of-two sub-chunks, the last of
        which is the fused-sample admit program.  Retraces are bounded by
        the bucketing: at most 3 scan programs + 2 log2(chunk) tail
        programs.
        """
        if length < 1:
            raise ValueError(f"cannot prefill an empty prompt ({length})")
        C = self.prefill_chunk
        tail = (length - 1) % C + 1
        n_full = (length - tail) // C
        steps: List[PlanStep] = []
        while n_full:
            m = min(_pow2_floor(n_full), _MAX_SCAN_CHUNKS)
            steps.append(("scan", m))
            n_full -= m
        while tail:
            s = _pow2_floor(tail)
            steps.append(("chunk", s))
            tail -= s
        steps[-1] = ("admit", steps[-1][1])
        return steps

    # ----------------------------------------------------------- staging
    def stage_begin(self, *, seed: int, rid: int, temperature: float,
                    top_k: int, top_p: float, eos_id, budget: int):
        """Reset the staging cache and record the request's sampling
        parameters.  The 1-row sampler state itself is built *inside* the
        fused admit program (key folded from (seed, rid) there, so the
        draw stream is independent of slot placement and tick length) —
        building it host-side would cost ~17 tiny dispatches per admit."""
        if not self._staging_clean:
            self.staging = self._staging_zeros()
        self._staging_clean = False
        self._staging_args = (
            np.int32(seed), np.int32(rid), np.float32(temperature),
            np.int32(top_k), np.float32(top_p),
            np.int32(-1 if eos_id is None else eos_id), np.int32(budget))
        self.staging_row = None
        self.staging_tok = None

    def _as_chunk(self, chunk, lead_shape):
        """Flat prompt slice -> device chunk.  (n,) int tokens or (n, d)
        float embeds (the stub VLM/audio frontends), reshaped to the
        program's chunk layout."""
        chunk = np.asarray(chunk)
        if chunk.dtype.kind == "f":
            x = jnp.asarray(chunk, jnp.dtype(self.cfg.act_dtype))
            return x.reshape(*lead_shape, x.shape[-1]), True
        return jnp.asarray(chunk, jnp.int32).reshape(lead_shape), False

    def stage_chunk_scan(self, chunks):
        """Advance staging by m full chunks in one dispatch.  chunks: flat
        (m * C,) tokens or (m * C, d) embeds."""
        m = len(chunks) // self.prefill_chunk
        x, is_embeds = self._as_chunk(chunks, (1, m, self.prefill_chunk))
        prog = self._scan_p.get((m, is_embeds))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"
            prog = jax.jit(
                lambda p, t, c, kw=kw: lm.prefill_chunk_scan(
                    p, self.cfg, c, **{kw: t}),
                donate_argnums=(2,))
            self._scan_p[(m, is_embeds)] = prog
        self.staging = prog(self.params, x, self.staging)

    def stage_chunk(self, chunk):
        """Advance staging by one interior tail sub-chunk (no logits)."""
        s = len(chunk)
        x, is_embeds = self._as_chunk(chunk, (1, s))
        prog = self._chunk_p.get((s, is_embeds))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"
            prog = jax.jit(
                lambda p, t, c, kw=kw: lm.prefill_chunk(
                    p, self.cfg, c, **{kw: t})[1],
                donate_argnums=(2,))
            self._chunk_p[(s, is_embeds)] = prog
        self.staging = prog(self.params, x, self.staging)

    def stage_admit(self, chunk) -> jax.Array:
        """Final sub-chunk + fused on-device first-token draw: one dispatch
        builds the request's sampler row (``sampling.admit_row``), prefills
        the chunk, samples the first token and advances the row (key split,
        budget decrement, EOS/budget done flag).  Returns the (1,) token
        array (still on device — the scheduler syncs it when it stamps
        TTFT) and leaves the advanced row for the slot scatter."""
        s = len(chunk)
        x, is_embeds = self._as_chunk(chunk, (1, s))
        prog = self._admit_p.get((s, is_embeds))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"

            def _admit(p, t, c, seed, rid, temp, top_k, top_p, eos, budget,
                       kw=kw):
                row = sampling.admit_row(seed, rid, temp, top_k, top_p,
                                         eos, budget)
                return lm.prefill_sample(p, self.cfg, c, row,
                                         sampling.sample, **{kw: t})

            prog = jax.jit(_admit, donate_argnums=(2,))
            self._admit_p[(s, is_embeds)] = prog
        self.staging_tok, self.staging_row, self.staging = prog(
            self.params, x, self.staging, *self._staging_args)
        return self.staging_tok

    def scatter(self, slot: int):
        """Scatter the completed staging cache + sampler row + first token
        into slot ``slot`` (one donated dispatch), then reset staging."""
        self.caches, self.sampler, self.tokens = self._scatter_p(
            self.caches, self.sampler, self.tokens, self.staging,
            self.staging_row, self.staging_tok, jnp.int32(slot))
        self.staging = self._staging_zeros()
        self._staging_clean = True
        self.staging_row = None
        self.staging_tok = None

    # ------------------------------------------------------------- ticks
    def decode(self, k: int):
        """One fused k-step decode+sample tick over all slots; the single
        host sync reads the (k, slots) token/validity arrays."""
        prog = self._decode_p.get(k)
        if prog is None:
            prog = jax.jit(
                lambda p, t, c, s, k=k: lm.decode_steps(
                    p, self.cfg, t, c, k,
                    sampler=s, sample_fn=sampling.sample),
                donate_argnums=(2, 3))
            self._decode_p[k] = prog
        toks, valid, self.tokens, self.caches, self.sampler = prog(
            self.params, self.tokens, self.caches, self.sampler)
        return np.asarray(toks), np.asarray(valid)
