"""Device executor: the serving engine's jitted donated-buffer programs.

This is the device half of the scheduler/executor split.  Everything that
touches an accelerator buffer lives here; everything that touches a
``Request`` lives in ``repro.serving.scheduler``.  The executor owns

  * the **slot buffers** — every layer's recurrent state / KV cache with a
    leading slot axis, the per-slot sampler arrays and the per-slot last
    tokens, all donated through every tick so XLA updates them in place
    (the TPU analogue of the paper's BRAM-resident state);
  * the **staging ring** — under the default **batched** staging
    (``prefill_batching``), ONE ``(staging_depth, ...)`` cache pytree
    whose rows are the staged prompts, plus a ``staging_depth``-row
    sampler state and per-row first tokens: every tick fuses ALL staged
    prompts into at most one fixed-shape ``(staging_depth,
    _MAX_SCAN_CHUNKS, prefill_chunk)`` scan + one admit dispatch with
    per-row ``valid_lens`` (rows/chunks past a prompt's end are bitwise
    no-op placeholders), and finished rows enter their slots through ONE
    multi-row scatter — dispatches per tick are O(1) in queue depth.
    The per-prompt fallback (pow2 plans, MoE FFNs, mixer kinds without
    per-row masks) keeps ``staging_depth`` single-sequence cache pytrees
    plus 1-row sampler states that chunked prefill streams into while
    the resident slots keep decoding, each scattered into a real slot
    only once its staging completes (the serving-layer version of the
    paper's prepare/compute/store overlap; a ring deeper than 1 lets
    several queued requests prefill ahead under saturation);
  * the **programs** — one jitted, donated program per static shape:
    - ``decode(k)``: the ``lm.decode_steps`` fused decode+sample scan, one
      program per bucketed tick length k (budget-aware ticks pick the
      smallest bucket covering the max remaining per-slot budget);
    - ``stage_chunk_scan`` / ``stage_chunk`` / ``stage_admit``: chunked
      prefill into a staging cache.  Under the default **masked planner**
      (``plan_mode="masked"``) a prompt dispatches at most TWO distinct
      program shapes: full chunks run m-at-a-time under one ``lax.scan``
      (one m per prompt, trailing slots masked out with per-chunk
      ``valid_len`` = 0), and the ragged tail is ONE fixed-size
      ``prefill_chunk``-sized chunk whose padded positions are masked by
      the per-token validity threading (kernels zero k/v/β/log-gate, the
      rolling KV insert drops padded slots) — the final state is provably
      that of the unpadded prompt, and the admit draw reads the logits of
      the last *valid* token.  ``plan_mode="pow2"`` keeps the PR-3
      power-of-two tail decomposition (no padding, no masking) as the
      comparison baseline.  The tail/admit program fuses the first-token
      draw on device (``lm.prefill_sample``), so admit never ships logits
      to the host; ring buffers share programs (same shapes);
    - ``scatter(slot, buf)``: one donated ``dynamic_update_slice`` over
      the whole staging pytree + sampler row + first token into ``slot``.

  Every program is compiled lazily on first use and cached by its static
  shape; ``compiled_programs()`` reports the live cache per family.  The
  masked planner bounds the prefill families at O(1) shapes per prompt
  (≤ _MAX_SCAN_CHUNKS scan lengths + 1 admit shape ever); the pow2
  baseline needs O(log chunk) tail programs on top.

**Mesh sharding.**  With ``mesh`` set (a ``("data", "model")`` device
mesh, see ``launch/mesh.py``), every buffer above is allocated with a
``NamedSharding`` derived from the existing sharding rules in
``parallel/sharding.py``: the slot axis on "data" (slot-axis data
parallelism), GDN/SSM state heads and the attention KV context dim on
"model" (the paper's 2→16 head-parallelism design axis scaled out over
devices), params TP-sharded by ``params_specs``, sampler rows and last
tokens slot-sharded on "data".  Every program is compiled with explicit
``in_shardings``/``out_shardings`` under that mesh, so the whole k-step
tick stays ONE SPMD program — there is no per-token cross-device sync
beyond the collectives GSPMD inserts inside it, and donated buffers keep
their placement across ticks.
"""
from __future__ import annotations

import warnings
from collections import deque
from typing import Any, Deque, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models import lm
from repro.serving import sampling


class PlanStep(NamedTuple):
    """One prefill dispatch.

    kind   : "scan" (m full chunks under one lax.scan) | "chunk" (one
             interior tail sub-chunk, pow2 mode only) | "admit" (final
             chunk + fused first-token draw).
    size   : the program's static shape — chunk count m for "scan",
             token count for "chunk"/"admit".
    tokens : valid prompt tokens consumed by this step (== the slice the
             scheduler feeds it; < the program capacity when masked).
    valid  : per-token validity threaded into the programs — "scan": an
             (m,)-tuple of per-chunk valid lengths (trailing 0-entries
             are placeholder chunks), "admit": the valid token count of
             the fixed-size tail; None = unmasked (pow2 baseline).
    """
    kind: str
    size: int
    tokens: int
    valid: Optional[Any] = None


# cap on chunks per scan dispatch: a single scan step is one program on the
# tick thread, so unbounded m would stall resident decode slots for nearly
# the whole prompt — bounding it keeps the overlap granular (and bounds
# the compile cache to scan programs of m in 1..4)
_MAX_SCAN_CHUNKS = 4


def _pow2_floor(n: int) -> int:
    return 1 << (n.bit_length() - 1)


class SwappedState(NamedTuple):
    """Host-side image of one request's device residency — what slot
    oversubscription pages out.  Every mixer's recurrent state is a
    constant-shape block described by ``cache_spec``, so the image is a
    fixed-size record, not a paged-KV block table:

    caches  : numpy pytree of ``(repeats, 1, ...)`` leaves — recurrent
              state + rolling KV window + position meta of every layer
              group, in exactly the staging layout the slot scatter
              admits from;
    sampler : 1-row sampler state (PRNG key mid-stream, remaining
              budget, done flag — see ``sampling.slice_row``);
    token   : (1,) int32 — the last emitted token, the next decode
              input.
    """
    caches: Any
    sampler: Dict[str, np.ndarray]
    token: np.ndarray

    @property
    def nbytes(self) -> int:
        """Bytes this image moves across the host boundary per swap."""
        leaves = (jax.tree.leaves(self.caches)
                  + list(self.sampler.values()) + [self.token])
        return int(sum(np.asarray(x).nbytes for x in leaves))


class PendingSwap:
    """Ledger entry for one in-flight asynchronous swap-out: the gathered
    device arrays (staging layout — they ARE the gather buffer, pinned
    alive by this record while ``copy_to_host_async`` drains them to host
    in the background) plus the gather-ring ticket ``buf`` that bounds
    how many drains may be outstanding.  ``DeviceExecutor.harvest``
    materializes the record into a ``SwappedState`` and only then
    returns the ticket — a draining buffer is never reused pre-harvest.
    """

    __slots__ = ("buf", "st", "row", "tok", "nbytes")

    def __init__(self, buf: int, st, row, tok):
        self.buf = buf
        self.st, self.row, self.tok = st, row, tok
        self.nbytes = int(sum(x.nbytes for x in
                              jax.tree.leaves((st, row, tok))))
        # start the background D2H drain; the later harvest device_get
        # then finds the host copy already (or mostly) resident
        for leaf in jax.tree.leaves((st, row, tok)):
            if hasattr(leaf, "copy_to_host_async"):
                leaf.copy_to_host_async()

    def ready(self) -> bool:
        """True when every gathered array's transfer has completed (the
        harvest device_get will not block).  Conservatively True when
        the backend lacks ``is_ready`` — the harvest still overlapped at
        least one full tick of compute."""
        return all(leaf.is_ready() for leaf in
                   jax.tree.leaves((self.st, self.row, self.tok))
                   if hasattr(leaf, "is_ready"))


def _gather_fn(caches, sampler, tokens, slot):
    """Slot gather — the inverse of ``_scatter_fn``: slice slot
    ``slot``'s cache column, sampler row and last token out of the slot
    buffers into the staging layout, and freeze the vacated slot's done
    flag so the remaining ticks treat it as inert.  The caches are
    read-only; only the sampler is donated (for the freeze)."""
    st = jax.tree.map(
        lambda f: jax.lax.dynamic_slice_in_dim(f, slot, 1, axis=1),
        caches)
    row = sampling.slice_row(sampler, slot)
    tok = jax.lax.dynamic_slice(tokens, (slot,), (1,))
    return st, row, tok, sampling.freeze_slot(sampler, slot)


def _bgather_fn(bstaging, bsampler, btoks, row):
    """Staging-row gather for the batched ring: slice row ``row``'s
    staged caches, admit-advanced sampler row and first token into the
    (repeats, 1, ...) staging layout ``restore_slot`` re-admits from.
    Pure read — the row is release-zeroed by the next multi-row
    scatter (the scheduler marks it dirty)."""
    st = jax.tree.map(
        lambda f: jax.lax.dynamic_slice_in_dim(f, row, 1, axis=1),
        bstaging)
    return (st, sampling.slice_row(bsampler, row),
            jax.lax.dynamic_slice(btoks, (row,), (1,)))


def _bscatter_fn(caches, sampler, tokens, bstaging, bsampler, btoks,
                 slots, release):
    """Multi-row scatter: admit every finished staging row in ONE
    dispatch.  ``slots`` is a (D,) int32 map from staging row to target
    slot, with the sentinel ``max_slots`` (out of bounds, dropped by
    ``mode="drop"``) for rows not admitting; ``release`` is a (D,) bool
    mask of rows to zero afterwards (admitted rows plus rows whose
    request finished at admit) so a released row is clean for the next
    ``bstage_begin``.  Distinct real slots per call is the scheduler's
    invariant — the scatter never sees duplicates."""
    caches = jax.tree.map(
        lambda f, o: f.at[:, slots].set(o.astype(f.dtype), mode="drop"),
        caches, bstaging)
    sampler = {
        k: v.at[slots].set(bsampler[k].astype(v.dtype), mode="drop")
        for k, v in sampler.items()}
    tokens = tokens.at[slots].set(btoks.astype(tokens.dtype), mode="drop")
    d = release.shape[0]
    bstaging = jax.tree.map(
        lambda o: jnp.where(release.reshape((1, d) + (1,) * (o.ndim - 2)),
                            jnp.zeros_like(o), o),
        bstaging)
    return caches, sampler, tokens, bstaging


def _scatter_fn(caches, sampler, tokens, staging, row, tok, slot):
    """Write the staging cache pytree, sampler row and first token into
    slot ``slot``.  Cache leaves are (repeats, slots, ...) vs
    (repeats, 1, ...); ``slot`` is traced so the whole-pytree scatter
    compiles once and runs in place (donated)."""
    caches = jax.tree.map(
        lambda f, o: jax.lax.dynamic_update_slice_in_dim(
            f, o.astype(f.dtype), slot, axis=1),
        caches, staging)
    sampler = {
        k: jax.lax.dynamic_update_slice_in_dim(
            v, row[k].astype(v.dtype), slot, axis=0)
        for k, v in sampler.items()}
    tokens = jax.lax.dynamic_update_slice(
        tokens, tok.astype(tokens.dtype), (slot,))
    return caches, sampler, tokens


class DeviceExecutor:
    """Owns the device buffers and jitted programs of one decode engine."""

    def __init__(self, cfg: ArchConfig, params, *, max_slots: int,
                 max_len: int, decode_block: int, prefill_chunk: int = 16,
                 mesh: Optional[Mesh] = None, staging_depth: int = 2,
                 plan_mode: str = "masked",
                 prefill_batching: Optional[bool] = None,
                 draft_cfg: Optional[ArchConfig] = None, draft_params=None,
                 k_draft: int = 4, async_paging: bool = False,
                 gather_ring: int = 2):
        if staging_depth < 1:
            raise ValueError(
                f"staging_depth must be >= 1, got {staging_depth}")
        if gather_ring < 1:
            raise ValueError(
                f"gather_ring must be >= 1, got {gather_ring}")
        if plan_mode not in ("masked", "pow2"):
            raise ValueError(f"plan_mode must be 'masked' or 'pow2', "
                             f"got {plan_mode!r}")
        # explicit validation — prefill_chunk is any size >= 1 (the masked
        # planner never assumes a power of two), but it must fit the
        # context buffers: a silently-clamped over-long chunk would hide a
        # misconfiguration
        if prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if prefill_chunk > max_len:
            raise ValueError(
                f"prefill_chunk={prefill_chunk} exceeds max_len={max_len}: "
                f"a prefill chunk can never hold more tokens than the "
                f"context buffers — lower prefill_chunk or raise max_len")
        if plan_mode == "masked":
            # masked plans need every mixer kind in the pattern to
            # implement the per-token validity mask; a kind registered
            # without it (third-party mixers) still serves — it just
            # falls back to the pow2 tail plans and pays the larger
            # compile cache
            from repro.models.mixers import get_mixer
            unsupported = sorted({k for k in cfg.pattern
                                  if not get_mixer(k)
                                  .supports_ragged_prefill})
            if unsupported:
                warnings.warn(
                    f"mixer kind(s) {unsupported} do not implement "
                    f"ragged (valid_len-masked) prefill chunks — falling "
                    f"back to plan_mode='pow2'; set "
                    f"supports_ragged_prefill = True after masking "
                    f"prefill_chunk to get fixed-shape plans",
                    RuntimeWarning)
                plan_mode = "pow2"
        # batched multi-prompt prefill: fuse every staged prompt into ONE
        # fixed-shape program per dispatch (per-row valid_lens; rows past
        # a prompt's end are bitwise no-op placeholder chunks).  Auto
        # (None) turns it on whenever it is provably bitwise-safe:
        #   * masked plans only — batching IS per-row masking;
        #   * every mixer kind must accept a per-row (B,) valid_len
        #     (supports_batched_ragged_prefill);
        #   * no MoE FFN: moe_fwd's expert-capacity queue is a cumsum
        #     over the whole (rows x tokens) dispatch group, so batched
        #     rows would compete for capacity and diverge bitwise from
        #     per-prompt dispatch.
        # An explicit True warns and falls back when a gate fails.
        batching_blocked = None
        if plan_mode != "masked":
            batching_blocked = ("batched staging rides on masked "
                                "(valid_len) chunks; plan_mode is "
                                f"{plan_mode!r}")
        elif cfg.ffn in ("moe", "moe+dense"):
            batching_blocked = (
                "MoE expert-capacity dispatch couples rows within a "
                "batch (cumsum queue positions over the whole group), "
                "so batched prefill cannot be bitwise-identical to "
                "per-prompt dispatch")
        else:
            from repro.models.mixers import get_mixer
            unbatched = sorted({k for k in cfg.pattern
                                if not get_mixer(k)
                                .supports_batched_ragged_prefill})
            if unbatched:
                batching_blocked = (
                    f"mixer kind(s) {unbatched} do not support per-row "
                    f"(B,) valid_len prefill chunks (set "
                    f"supports_batched_ragged_prefill = True after "
                    f"generalizing the mask)")
        if prefill_batching and batching_blocked:
            warnings.warn(f"prefill_batching disabled: {batching_blocked}",
                          RuntimeWarning)
        self.prefill_batching = (batching_blocked is None
                                 if prefill_batching is None
                                 else bool(prefill_batching)
                                 and batching_blocked is None)
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.decode_block = decode_block
        self.mesh = mesh
        self.staging_depth = staging_depth
        self.plan_mode = plan_mode
        # chunks scatter into rolling KV buffers, whose size is
        # min(window, max_len) — one chunk must not wrap a buffer, so the
        # chunk is clamped to the smallest rolling window (documented
        # invariant of attn_prefill_chunk, checked there too)
        limit = min(max_len, cfg.window) if cfg.window else max_len
        self.prefill_chunk = min(prefill_chunk, limit)

        # spec-driven slot buffers: shapes, dtypes and byte budgets all
        # come from the mixers' declarative cache specs
        self.spec = lm.cache_specs(cfg, max_slots, max_len)
        slot_spec = lm.cache_specs(cfg, 1, max_len)
        self.state_bytes_per_slot = slot_spec.state_bytes
        self.window_bytes_per_slot = slot_spec.window_bytes
        self.cache_bytes = self.spec.nbytes
        # spec-derived swap budget: what one swapped request moves across
        # the host boundary each direction (cache column + sampler row +
        # last token) — benchmarks report swap µs/MB against this
        samp1 = jax.eval_shape(lambda: sampling.init_state(1))
        self.swap_bytes_per_slot = slot_spec.nbytes + int(sum(
            np.dtype(x.dtype).itemsize * int(np.prod(x.shape))
            for x in jax.tree.leaves(samp1))) + 4

        self._build_shardings(params)
        self.params = (params if mesh is None else
                       jax.device_put(params, self._sh_params))
        self.caches = self._zeros(self.spec, self._sh_caches)
        self.tokens = self._put(jnp.zeros((max_slots,), jnp.int32),
                                self._sh_tokens)
        self.sampler = self._put(sampling.init_state(max_slots),
                                 self._sh_sampler)

        # ---- speculative decode (draft model slot + rollback buffers) --
        # The swap image (swap_bytes_per_slot) deliberately excludes ALL
        # of the buffers below: draft caches are rebuilt from the consumed
        # token stream at swap-in (draft_prefill_slot) and checkpoints are
        # scratch that never survives a verify boundary, so a speculative
        # engine's swapped state stays interchangeable with a
        # non-speculative engine's.
        self.speculative = draft_cfg is not None
        self.k_draft = k_draft
        if self.speculative:
            if k_draft < 1:
                raise ValueError(f"k_draft must be >= 1, got {k_draft}")
            if draft_cfg.vocab != cfg.vocab:
                raise ValueError(
                    f"draft model must share the target vocab "
                    f"({draft_cfg.vocab} != {cfg.vocab}) — draft proposals "
                    f"are token ids the target verifies")
            from repro.models.mixers import get_mixer
            unsupported = sorted({k for k in draft_cfg.pattern
                                  if not get_mixer(k)
                                  .supports_ragged_prefill})
            if unsupported:
                raise ValueError(
                    f"draft mixer kind(s) {unsupported} do not support "
                    f"ragged (valid_len-masked) prefill chunks — the "
                    f"draft state rebuild at slot activation runs one "
                    f"fixed-shape masked chunk scan")
            self.draft_cfg = draft_cfg
            # rollback images, straight from the mixers' declarative
            # checkpoint specs (default: one full extra state copy per
            # slot); the registry propagates any narrower per-kind
            # declaration here, to the sharding planner and to the
            # intensity model without engine edits
            self.ckpt_spec = lm.checkpoint_specs(cfg, max_slots, max_len)
            self.dspec = lm.cache_specs(draft_cfg, max_slots, max_len)
            self.dckpt_spec = lm.checkpoint_specs(draft_cfg, max_slots,
                                                  max_len)
            self.checkpoint_bytes_per_slot = lm.checkpoint_specs(
                cfg, 1, max_len).nbytes
            self.draft_bytes_per_slot = (
                lm.cache_specs(draft_cfg, 1, max_len).nbytes
                + lm.checkpoint_specs(draft_cfg, 1, max_len).nbytes)
            self.speculative_bytes = (self.ckpt_spec.nbytes
                                      + self.dspec.nbytes
                                      + self.dckpt_spec.nbytes)
            if mesh is None:
                self._sh_dparams = self._sh_dcaches = None
                self._sh_ckpt = self._sh_dckpt = None
            else:
                from repro.parallel import sharding as rules
                self._sh_ckpt = rules.make_shardings(
                    mesh, rules.checkpoint_specs(
                        cfg, mesh, self.ckpt_spec.shape_dtype(), max_slots))
                self._sh_dcaches = rules.make_shardings(
                    mesh, rules.slot_specs(draft_cfg, mesh,
                                           self.dspec.shape_dtype(),
                                           max_slots))
                self._sh_dckpt = rules.make_shardings(
                    mesh, rules.checkpoint_specs(
                        draft_cfg, mesh, self.dckpt_spec.shape_dtype(),
                        max_slots))
                self._sh_dparams = (
                    self._sh_params if draft_params is params else
                    rules.make_shardings(
                        mesh, rules.params_specs(draft_cfg, draft_params,
                                                 False, mesh)))
            self.draft_params = (
                self.params if draft_params is params else
                (draft_params if mesh is None else
                 jax.device_put(draft_params, self._sh_dparams)))
            self.dcaches = self._zeros(self.dspec, self._sh_dcaches)
            self.ckpt = self._zeros(self.ckpt_spec, self._sh_ckpt)
            self.dckpt = self._zeros(self.dckpt_spec, self._sh_dckpt)
            # draft prompt-prefill geometry: one fixed (1, n, C) masked
            # chunk scan covers any consumed-token count <= max_len, with
            # the SAME chunk size as the target's staged prefill so a
            # self-draft's rebuilt state hits the same chunk boundaries
            dlimit = (min(max_len, draft_cfg.window) if draft_cfg.window
                      else max_len)
            self._dchunk = min(self.prefill_chunk, dlimit)
            self._dchunks = -(-max_len // self._dchunk)
            self._draft_p: Dict[int, object] = {}
            self._verify_p: Dict[int, object] = {}
            self._dprefill_p = None

        # staging ring (prefill overlap targets); the sampler rows are
        # produced by the fused admit program, not materialized up front
        self._staging_zeros = self._jit(
            lambda: lm.init_caches(cfg, 1, max_len),
            out_sh=self._sh_staging)
        self.staging: List[Any] = [self._staging_zeros()
                                   for _ in range(staging_depth)]
        self._staging_clean = [True] * staging_depth
        self._staging_args: List[Optional[tuple]] = [None] * staging_depth
        self.staging_row: List[Any] = [None] * staging_depth
        self.staging_tok: List[Optional[jax.Array]] = [None] * staging_depth

        # lazily-built program caches, keyed by static shape
        # (+ masked flag for the prefill families — a masked program takes
        # the validity array as an extra operand)
        self._decode_p: Dict[int, object] = {}
        self._scan_p: Dict[Tuple[int, bool, bool], object] = {}
        self._chunk_p: Dict[Tuple[int, bool], object] = {}
        self._admit_p: Dict[Tuple[int, bool, bool], object] = {}
        # batched staging (lazy): one (staging_depth, ...) cache pytree, a
        # staging_depth-row sampler and per-row first tokens, plus the
        # batched program caches — allocated on the first batched call so
        # engines running the per-prompt path pay nothing.  The batched
        # scan always runs at the fixed shape (D, _MAX_SCAN_CHUNKS, C)
        # (rows with fewer chunks pad with valid_len = 0 placeholders), so
        # the whole batched family is ≤ 2 programs per input kind + one
        # multi-row scatter — the paper's fixed-iteration datapath.
        self._batched_ready = False
        self._bscan_p: Dict[bool, object] = {}
        self._badmit_p: Dict[bool, object] = {}
        self._bscatter_p = None
        # state-paging gathers (lazy — engines that never swap pay nothing)
        self._gather_p = None
        self._bgather_p = None
        # async-paging gather ring: ``gather_ring`` tickets bound how many
        # swap-outs may drain D2H concurrently.  The gathered arrays (the
        # _gather_p outputs are fresh, never-donated buffers) double as
        # the ring's storage, so the ledger is just the ticket ids: a
        # ticket leaves ``_gather_free`` at dispatch and returns only at
        # ``harvest`` — XLA cannot recycle a draining buffer because the
        # PendingSwap holds the only live reference until then.
        self.async_paging = bool(async_paging)
        self.gather_ring = gather_ring
        self._gather_free: Deque[int] = deque(range(gather_ring))
        self._gather_pending: Dict[int, PendingSwap] = {}
        # donate only the slot buffers: the staging pytree's (repeats, 1,
        # ...) leaves have no same-shape output to alias (XLA would warn)
        self._scatter_p = self._jit(
            _scatter_fn, donate=(0, 1, 2),
            in_sh=(self._sh_caches, self._sh_sampler, self._sh_tokens,
                   self._sh_staging, self._sh_row, self._sh_rep,
                   self._sh_rep),
            out_sh=(self._sh_caches, self._sh_sampler, self._sh_tokens))

    # --------------------------------------------------------- shardings
    def _build_shardings(self, params):
        """Derive every buffer's NamedSharding from the rules in
        ``parallel/sharding.py`` (None placeholders when no mesh)."""
        if self.mesh is None:
            (self._sh_params, self._sh_caches, self._sh_staging,
             self._sh_sampler, self._sh_tokens, self._sh_row,
             self._sh_rep, self._sh_toks2d) = (None,) * 8
            return
        from repro.parallel import sharding as rules
        mesh = self.mesh
        if self.max_slots % rules.axis_size(mesh, rules.dp_axes(mesh)):
            warnings.warn(
                f"max_slots={self.max_slots} does not divide the data axis "
                f"({rules.axis_size(mesh, rules.dp_axes(mesh))}); the slot "
                f"axis cannot shard evenly (fit_spec will replicate it or "
                f"re-place 'data' on a state dim, losing the bitwise "
                f"stream guarantee) — pad slots with "
                f"ServingTopology.pad_slots", RuntimeWarning)
        cache_ps = rules.slot_specs(self.cfg, mesh, self.spec.shape_dtype(),
                                    self.max_slots)
        self._sh_caches = rules.make_shardings(mesh, cache_ps)
        self._sh_staging = rules.make_shardings(
            mesh, rules.staging_specs(cache_ps))
        self._sh_params = rules.make_shardings(
            mesh, rules.params_specs(self.cfg, params, False, mesh))
        samp = jax.eval_shape(lambda: sampling.init_state(self.max_slots))
        self._sh_sampler = rules.make_shardings(
            mesh, rules.sampler_specs(mesh, samp, self.max_slots))
        tok_spec = rules.token_slot_spec(mesh, self.max_slots)
        self._sh_tokens = NamedSharding(mesh, tok_spec)
        self._sh_row = rules.replicated(mesh, samp)
        self._sh_rep = NamedSharding(mesh, P())
        self._sh_toks2d = NamedSharding(mesh, P(None, *tok_spec))

    def _jit(self, fn, *, donate=(), in_sh=None, out_sh=None):
        """jit with explicit in/out shardings when running under a mesh
        (every program is one SPMD program over the whole mesh), plain
        jit otherwise."""
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        kw = {}
        if in_sh is not None:
            kw["in_shardings"] = in_sh
        if out_sh is not None:
            kw["out_shardings"] = out_sh
        return jax.jit(fn, donate_argnums=donate, **kw)

    def _zeros(self, spec, shardings):
        if self.mesh is None:
            return spec.zeros()
        return jax.jit(spec.zeros, out_shardings=shardings)()

    def _put(self, tree, shardings):
        return tree if self.mesh is None else jax.device_put(tree,
                                                             shardings)

    def _rep_sh(self, n: int):
        """in_shardings entry for n replicated (scalar/host) args."""
        return (self._sh_rep,) * n

    # ------------------------------------------------------------- plans
    def plan_prefill(self, length: int) -> List[PlanStep]:
        """Decompose a prompt of ``length`` tokens into dispatch steps.

        **masked** (default): at most TWO distinct program shapes per
        prompt.  Full chunks run under ONE scan shape m = the balanced
        chunk count ≤ ``_MAX_SCAN_CHUNKS`` (the last dispatch pads with
        valid_len = 0 placeholder chunks — exact no-ops on the caches),
        and the ragged tail is ONE fixed-size masked admit chunk (its
        padded positions carry valid_len, so the admit logits come from
        the last real token).  The compile cache is bounded at
        ``_MAX_SCAN_CHUNKS`` scan shapes + 1 admit shape *total across
        all prompt lengths*.

        **pow2** (baseline): the PR-3 decomposition — power-of-two scan
        counts, power-of-two unmasked tail sub-chunks, the last being the
        fused-sample admit.  No padding, but O(log chunk) tail programs.

        Both planners dispatch the same valid tokens through the same
        per-chunk math, so token streams agree (pinned by
        ``tests/test_ragged_prefill.py``).
        """
        if length < 1:
            raise ValueError(f"cannot prefill an empty prompt ({length})")
        C = self.prefill_chunk
        tail = (length - 1) % C + 1
        n_full = (length - tail) // C
        steps: List[PlanStep] = []
        if self.plan_mode == "pow2":
            while n_full:
                m = min(_pow2_floor(n_full), _MAX_SCAN_CHUNKS)
                steps.append(PlanStep("scan", m, m * C))
                n_full -= m
            while tail:
                s = _pow2_floor(tail)
                steps.append(PlanStep("chunk", s, s))
                tail -= s
            last = steps[-1]
            steps[-1] = PlanStep("admit", last.size, last.tokens)
            return steps
        if n_full:
            # one scan shape per prompt: the balanced chunk count needs
            # the fewest placeholder chunks for the dispatch count the
            # _MAX_SCAN_CHUNKS cap forces (e.g. 5 full chunks -> two
            # dispatches of m=3, one placeholder, not 4+1)
            n_disp = -(-n_full // _MAX_SCAN_CHUNKS)
            m = -(-n_full // n_disp)
            left = n_full
            for _ in range(n_disp):
                r = min(left, m)
                steps.append(PlanStep("scan", m, r * C,
                                      (C,) * r + (0,) * (m - r)))
                left -= r
        steps.append(PlanStep("admit", C, tail, tail))
        return steps

    # ----------------------------------------------------------- staging
    def stage_begin(self, buf: int, *, seed: int, rid: int,
                    temperature: float, top_k: int, top_p: float,
                    eos_id, budget: int):
        """Reset ring buffer ``buf``'s staging cache and record the
        request's sampling parameters.  The 1-row sampler state itself is
        built *inside* the fused admit program (key folded from
        (seed, rid) there, so the draw stream is independent of slot
        placement, staging buffer and tick length) — building it
        host-side would cost ~17 tiny dispatches per admit."""
        if not self._staging_clean[buf]:
            self.staging[buf] = self._staging_zeros()
        self._staging_clean[buf] = False
        self._staging_args[buf] = (
            np.int32(seed), np.int32(rid), np.float32(temperature),
            np.int32(top_k), np.float32(top_p),
            np.int32(-1 if eos_id is None else eos_id), np.int32(budget))
        self.staging_row[buf] = None
        self.staging_tok[buf] = None

    def _as_chunk(self, chunk, lead_shape, pad_to: int = 0):
        """Flat prompt slice -> device chunk.  (n,) int tokens or (n, d)
        float embeds (the stub VLM/audio frontends), zero-padded to
        ``pad_to`` tokens when the slice is ragged, reshaped to the
        program's chunk layout."""
        chunk = np.asarray(chunk)
        if pad_to > chunk.shape[0]:
            pad = np.zeros((pad_to - chunk.shape[0],) + chunk.shape[1:],
                           chunk.dtype)
            chunk = np.concatenate([chunk, pad])
        if chunk.dtype.kind == "f":
            x = jnp.asarray(chunk, jnp.dtype(self.cfg.act_dtype))
            return x.reshape(*lead_shape, x.shape[-1]), True
        return jnp.asarray(chunk, jnp.int32).reshape(lead_shape), False

    def stage_chunk_scan(self, buf: int, chunks, valid_lens=None):
        """Advance ring buffer ``buf`` by m chunks in one dispatch.

        chunks: flat tokens (or (n, d) embeds) — m * C of them unmasked,
        or ``sum(valid_lens)`` for a masked dispatch (``valid_lens`` an
        (m,)-tuple of per-chunk valid counts; the slice is zero-padded
        into the fixed (m, C) layout and each chunk's padding is masked
        by the per-token validity threading — a 0-entry is a placeholder
        chunk that leaves the caches untouched)."""
        C = self.prefill_chunk
        masked = valid_lens is not None
        m = len(valid_lens) if masked else len(chunks) // C
        x, is_embeds = self._as_chunk(chunks, (1, m, C),
                                      pad_to=m * C if masked else 0)
        prog = self._scan_p.get((m, is_embeds, masked))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"
            if masked:
                prog = self._jit(
                    lambda p, t, vl, c, kw=kw: lm.prefill_chunk_scan(
                        p, self.cfg, c, valid_lens=vl, **{kw: t}),
                    donate=(3,),
                    in_sh=(self._sh_params, self._sh_rep, self._sh_rep,
                           self._sh_staging),
                    out_sh=self._sh_staging)
            else:
                prog = self._jit(
                    lambda p, t, c, kw=kw: lm.prefill_chunk_scan(
                        p, self.cfg, c, **{kw: t}),
                    donate=(2,),
                    in_sh=(self._sh_params, self._sh_rep, self._sh_staging),
                    out_sh=self._sh_staging)
            self._scan_p[(m, is_embeds, masked)] = prog
        if masked:
            vl = jnp.asarray(np.asarray(valid_lens, np.int32))
            self.staging[buf] = prog(self.params, x, vl, self.staging[buf])
        else:
            self.staging[buf] = prog(self.params, x, self.staging[buf])

    def stage_chunk(self, buf: int, chunk):
        """Advance ring buffer ``buf`` by one interior tail sub-chunk
        (no logits; pow2 plans only — the masked planner's tail is a
        single fixed-size admit chunk)."""
        s = len(chunk)
        x, is_embeds = self._as_chunk(chunk, (1, s))
        prog = self._chunk_p.get((s, is_embeds))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"
            prog = self._jit(
                lambda p, t, c, kw=kw: lm.prefill_chunk(
                    p, self.cfg, c, **{kw: t})[1],
                donate=(2,),
                in_sh=(self._sh_params, self._sh_rep, self._sh_staging),
                out_sh=self._sh_staging)
            self._chunk_p[(s, is_embeds)] = prog
        self.staging[buf] = prog(self.params, x, self.staging[buf])

    def stage_admit(self, buf: int, chunk, valid_len=None) -> jax.Array:
        """Final chunk + fused on-device first-token draw: one dispatch
        builds the request's sampler row (``sampling.admit_row``), prefills
        the chunk, samples the first token and advances the row (key split,
        budget decrement, EOS/budget done flag).  Returns the (1,) token
        array (still on device — the scheduler syncs it when it stamps
        TTFT) and leaves the advanced row for the slot scatter.

        With ``valid_len`` set the chunk is the masked planner's
        fixed-size tail: the slice is zero-padded to ``prefill_chunk``
        tokens and the programs read the admit logits from the last
        *valid* position."""
        masked = valid_len is not None
        s = self.prefill_chunk if masked else len(chunk)
        x, is_embeds = self._as_chunk(chunk, (1, s),
                                      pad_to=s if masked else 0)
        prog = self._admit_p.get((s, is_embeds, masked))
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"

            if masked:
                def _admit(p, t, c, vl, seed, rid, temp, top_k, top_p,
                           eos, budget, kw=kw):
                    row = sampling.admit_row(seed, rid, temp, top_k, top_p,
                                             eos, budget)
                    return lm.prefill_sample(p, self.cfg, c, row,
                                             sampling.sample, valid_len=vl,
                                             **{kw: t})
                n_rep = 8
            else:
                def _admit(p, t, c, seed, rid, temp, top_k, top_p, eos,
                           budget, kw=kw):
                    row = sampling.admit_row(seed, rid, temp, top_k, top_p,
                                             eos, budget)
                    return lm.prefill_sample(p, self.cfg, c, row,
                                             sampling.sample, **{kw: t})
                n_rep = 7

            prog = self._jit(
                _admit, donate=(2,),
                in_sh=((self._sh_params, self._sh_rep, self._sh_staging)
                       + self._rep_sh(n_rep)
                       if self.mesh is not None else None),
                out_sh=((self._sh_rep, self._sh_row, self._sh_staging)
                        if self.mesh is not None else None))
            self._admit_p[(s, is_embeds, masked)] = prog
        extra = ((np.int32(valid_len),) if masked else ())
        self.staging_tok[buf], self.staging_row[buf], self.staging[buf] = \
            prog(self.params, x, self.staging[buf],
                 *extra, *self._staging_args[buf])
        return self.staging_tok[buf]

    def scatter(self, slot: int, buf: int):
        """Scatter ring buffer ``buf``'s completed staging cache + sampler
        row + first token into slot ``slot`` (one donated dispatch), then
        reset that ring buffer."""
        self.caches, self.sampler, self.tokens = self._scatter_p(
            self.caches, self.sampler, self.tokens, self.staging[buf],
            self.staging_row[buf], self.staging_tok[buf], jnp.int32(slot))
        self.staging[buf] = self._staging_zeros()
        self._staging_clean[buf] = True
        self.staging_row[buf] = None
        self.staging_tok[buf] = None

    # ------------------------------------------------- batched staging
    def _ensure_batched(self):
        """Allocate the batched staging buffers + multi-row scatter on
        first use: ONE (staging_depth, ...) cache pytree (every staged
        prompt is a row), a staging_depth-row sampler state holding the
        advanced admit rows, and the (staging_depth,) first tokens.  Under
        a mesh the row axis shards on "data" exactly like the slot axis
        (``slot_specs`` with batch = staging_depth)."""
        if self._batched_ready:
            return
        D = self.staging_depth
        self.bspec = lm.cache_specs(self.cfg, D, self.max_len)
        if self.mesh is None:
            self._sh_bstaging = self._sh_bsampler = self._sh_btoks = None
        else:
            from repro.parallel import sharding as rules
            mesh = self.mesh
            ps = rules.slot_specs(self.cfg, mesh, self.bspec.shape_dtype(),
                                  D)
            if D % rules.axis_size(mesh, rules.dp_axes(mesh)):
                # a non-dividing row count must not re-place DP axes on a
                # state dim (cache_specs' tiny-batch rule): distributed
                # state reductions would break the bitwise batching
                # guarantee — replicate the rows instead, keeping only the
                # "model" (head / KV context) placement
                dp = set(rules.dp_axes(mesh))

                def _drop_dp(s):
                    return P(*[None if (a in dp or (isinstance(a, tuple)
                                                    and set(a) & dp))
                               else a for a in s])
                ps = jax.tree.map(_drop_dp, ps,
                                  is_leaf=lambda x: isinstance(x, P))
            self._sh_bstaging = rules.make_shardings(mesh, ps)
            samp = jax.eval_shape(lambda: sampling.init_state(D))
            self._sh_bsampler = rules.make_shardings(
                mesh, rules.sampler_specs(mesh, samp, D))
            self._sh_btoks = NamedSharding(
                mesh, rules.token_slot_spec(mesh, D))
        self.bstaging = self._zeros(self.bspec, self._sh_bstaging)
        self.bsampler = self._put(sampling.init_state(D),
                                  self._sh_bsampler)
        self.btoks = self._put(jnp.zeros((D,), jnp.int32), self._sh_btoks)
        # host mirror of per-row sampling parameters (written by
        # bstage_begin, shipped whole into every batched admit dispatch;
        # rows not admitting carry stale values the admit mask discards)
        self._bargs = {
            "rid": np.zeros((D,), np.int32),
            "temperature": np.zeros((D,), np.float32),
            "top_k": np.zeros((D,), np.int32),
            "top_p": np.ones((D,), np.float32),
            "eos_id": np.full((D,), -1, np.int32),
            "budget": np.ones((D,), np.int32),
        }
        self._bseed = np.int32(0)
        self._bscatter_p = self._jit(
            _bscatter_fn, donate=(0, 1, 2, 3),
            in_sh=(self._sh_caches, self._sh_sampler, self._sh_tokens,
                   self._sh_bstaging, self._sh_bsampler, self._sh_btoks,
                   self._sh_rep, self._sh_rep),
            out_sh=(self._sh_caches, self._sh_sampler, self._sh_tokens,
                    self._sh_bstaging))
        self._batched_ready = True

    def bstage_begin(self, row: int, *, seed: int, rid: int,
                     temperature: float, top_k: int, top_p: float,
                     eos_id, budget: int):
        """Record a request's sampling parameters for staging row ``row``
        (host-only — no dispatch).  The row's staging caches are already
        zero: rows are release-zeroed inside the multi-row scatter, so
        beginning a row never costs a device program."""
        self._ensure_batched()
        self._bseed = np.int32(seed)
        self._bargs["rid"][row] = rid
        self._bargs["temperature"][row] = temperature
        self._bargs["top_k"][row] = top_k
        self._bargs["top_p"][row] = top_p
        self._bargs["eos_id"][row] = -1 if eos_id is None else eos_id
        self._bargs["budget"][row] = budget

    def bstage_chunk_scan(self, entries):
        """Advance several staging rows by their next full chunks in ONE
        fixed-shape dispatch.

        entries: list of ``(row, flat_chunk, take)`` — ``take`` full
        chunks (take * C tokens, or (take * C, d) embeds) for row
        ``row``.  Every dispatch runs the same (D, _MAX_SCAN_CHUNKS, C)
        program: rows taking fewer chunks (and rows with no entry) pad
        with valid_len = 0 placeholder chunks, which are bitwise no-ops
        on their caches — the fixed five-phase iteration regardless of
        occupancy."""
        D, C, M = self.staging_depth, self.prefill_chunk, _MAX_SCAN_CHUNKS
        self._ensure_batched()
        first = np.asarray(entries[0][1])
        is_embeds = first.dtype.kind == "f"
        vl = np.zeros((M, D), np.int32)
        if is_embeds:
            x = np.zeros((D, M, C, first.shape[-1]), first.dtype)
        else:
            x = np.zeros((D, M, C), np.int32)
        for row, chunk, take in entries:
            chunk = np.asarray(chunk)
            x[row, :take] = chunk.reshape((take, C) + chunk.shape[1:])
            vl[:take, row] = C
        prog = self._bscan_p.get(is_embeds)
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"
            prog = self._jit(
                lambda p, t, v, c, kw=kw: lm.prefill_chunk_scan(
                    p, self.cfg, c, valid_lens=v, **{kw: t}),
                donate=(3,),
                in_sh=(self._sh_params, self._sh_rep, self._sh_rep,
                       self._sh_bstaging),
                out_sh=self._sh_bstaging)
            self._bscan_p[is_embeds] = prog
        xj = (jnp.asarray(x, jnp.dtype(self.cfg.act_dtype)) if is_embeds
              else jnp.asarray(x))
        self.bstaging = prog(self.params, xj, jnp.asarray(vl),
                             self.bstaging)

    def bstage_admit(self, entries):
        """Final (ragged tail) chunk + fused first-token draw for several
        staging rows in ONE dispatch: builds every admitting row's sampler
        state on device (``sampling.admit_rows`` — keys folded from
        (seed, rid) exactly as the per-prompt path does, so draw streams
        are batching-invariant), prefills the fixed-size masked tail,
        samples, and merges tokens/sampler rows under the admit mask
        (rows not admitting are valid_len = 0 cache no-ops and keep their
        previous token/sampler values).

        entries: list of ``(row, flat_chunk, valid_len)`` with
        1 <= valid_len <= prefill_chunk tokens in ``flat_chunk``."""
        D, C = self.staging_depth, self.prefill_chunk
        self._ensure_batched()
        first = np.asarray(entries[0][1])
        is_embeds = first.dtype.kind == "f"
        vl = np.zeros((D,), np.int32)
        amask = np.zeros((D,), bool)
        if is_embeds:
            x = np.zeros((D, C, first.shape[-1]), first.dtype)
        else:
            x = np.zeros((D, C), np.int32)
        for row, chunk, valid in entries:
            chunk = np.asarray(chunk)
            x[row, :valid] = chunk
            vl[row] = valid
            amask[row] = True
        prog = self._badmit_p.get(is_embeds)
        if prog is None:
            kw = "embeds" if is_embeds else "tokens"

            def _badmit(p, t, c, samp, toks, v, am, seed, rid, temp,
                        top_k, top_p, eos, budget, kw=kw):
                rows = sampling.admit_rows(seed, rid, temp, top_k, top_p,
                                           eos, budget)
                tok, rows, c = lm.prefill_sample(
                    p, self.cfg, c, rows, sampling.sample, valid_len=v,
                    **{kw: t})
                toks = jnp.where(am, tok.astype(toks.dtype), toks)
                samp = {
                    k: jnp.where(
                        am.reshape((-1,) + (1,) * (w.ndim - 1)),
                        rows[k].astype(w.dtype), w)
                    for k, w in samp.items()}
                return toks, samp, c

            prog = self._jit(
                _badmit, donate=(2, 3, 4),
                in_sh=((self._sh_params, self._sh_rep, self._sh_bstaging,
                        self._sh_bsampler, self._sh_btoks)
                       + self._rep_sh(9)
                       if self.mesh is not None else None),
                out_sh=((self._sh_btoks, self._sh_bsampler,
                         self._sh_bstaging)
                        if self.mesh is not None else None))
            self._badmit_p[is_embeds] = prog
        xj = (jnp.asarray(x, jnp.dtype(self.cfg.act_dtype)) if is_embeds
              else jnp.asarray(x))
        self.btoks, self.bsampler, self.bstaging = prog(
            self.params, xj, self.bstaging, self.bsampler, self.btoks,
            jnp.asarray(vl), jnp.asarray(amask), self._bseed,
            self._bargs["rid"], self._bargs["temperature"],
            self._bargs["top_k"], self._bargs["top_p"],
            self._bargs["eos_id"], self._bargs["budget"])

    def bscatter(self, assigns, release_rows=()):
        """Admit every finished staging row into its slot in ONE donated
        dispatch.  assigns: list of ``(slot, row)`` pairs (distinct
        slots); release_rows: extra rows to zero without scattering
        (requests that finished at admit).  Assigned rows are always
        released — after the scatter both are clean for reuse."""
        self._ensure_batched()
        slots = np.full((self.staging_depth,), self.max_slots, np.int32)
        release = np.zeros((self.staging_depth,), bool)
        for slot, row in assigns:
            slots[row] = slot
            release[row] = True
        for row in release_rows:
            release[row] = True
        (self.caches, self.sampler, self.tokens,
         self.bstaging) = self._bscatter_p(
            self.caches, self.sampler, self.tokens, self.bstaging,
            self.bsampler, self.btoks, jnp.asarray(slots),
            jnp.asarray(release))

    # ------------------------------------------------------ state paging
    def _host_state(self, st, row, tok) -> SwappedState:
        """Fetch a gathered (staging-layout) slice to host memory.  Under
        a mesh the fetch is the all-gather to one replicated host copy —
        the swapped image is topology-free, so any engine with the same
        arch config (any mesh shape) can restore it."""
        st, row, tok = jax.device_get((st, row, tok))
        return SwappedState(caches=st, sampler=row, token=np.asarray(tok))

    def _acquire_ticket(self) -> int:
        """Claim a gather-ring ticket for one async swap-out dispatch.
        The scheduler is responsible for capacity (force-harvesting the
        oldest drain when the ring is full), so an empty ring here is a
        ledger bug, not backpressure."""
        if not self._gather_free:
            raise RuntimeError(
                f"gather ring exhausted: all {self.gather_ring} buffers "
                f"are draining — harvest a pending swap before "
                f"dispatching another gather")
        return self._gather_free.popleft()

    def gather_slot_async(self, slot: int) -> PendingSwap:
        """Dispatch the swap-out of resident slot ``slot`` without
        waiting for the D2H transfer: ONE program slices its cache
        column + sampler row + last token (the inverse of the slot
        scatter) and freezes the vacated slot's done flag; the fresh
        output arrays become a gather-ring buffer whose host copy drains
        in the background (``copy_to_host_async`` inside PendingSwap).
        The slot is reusable the moment this returns — the gathered
        values are a snapshot, so later scatters into the slot cannot
        perturb the eventual ``harvest``."""
        if self._gather_p is None:
            self._gather_p = self._jit(
                _gather_fn, donate=(1,),
                in_sh=(self._sh_caches, self._sh_sampler, self._sh_tokens,
                       self._sh_rep),
                out_sh=((self._sh_staging, self._sh_row, self._sh_rep,
                         self._sh_sampler)
                        if self.mesh is not None else None))
        buf = self._acquire_ticket()
        st, row, tok, self.sampler = self._gather_p(
            self.caches, self.sampler, self.tokens, jnp.int32(slot))
        pend = PendingSwap(buf, st, row, tok)
        self._gather_pending[buf] = pend
        return pend

    def gather_staging_async(self, buf_ring: int) -> PendingSwap:
        """Dispatch the swap-out of per-prompt ring buffer ``buf_ring``
        (a staged-ready request pausing at the admit boundary, before
        its slot scatter): the staging cache, admit-advanced sampler row
        and first token are already in staging layout — no program, the
        PendingSwap takes direct refs.  Holding them across a later
        ``stage_begin`` is safe: that path REPLACES ``staging[buf]``
        with fresh zeros, it never donates the old arrays.  The buffer
        returns to the ring dirty (``stage_begin`` re-zeros it)."""
        buf = self._acquire_ticket()
        pend = PendingSwap(buf, self.staging[buf_ring],
                           self.staging_row[buf_ring],
                           self.staging_tok[buf_ring])
        self.staging_row[buf_ring] = None
        self.staging_tok[buf_ring] = None
        self._gather_pending[buf] = pend
        return pend

    def bgather_row_async(self, row: int) -> PendingSwap:
        """Dispatch the swap-out of batched staging row ``row`` (the
        admit-boundary swap on the batched path).  Pure read — the
        caller marks the row dirty so the next multi-row scatter
        release-zeroes it; the gather outputs are fresh arrays, immune
        to that zeroing."""
        self._ensure_batched()
        if self._bgather_p is None:
            self._bgather_p = self._jit(
                _bgather_fn,
                in_sh=(self._sh_bstaging, self._sh_bsampler,
                       self._sh_btoks, self._sh_rep),
                out_sh=((self._sh_staging, self._sh_row, self._sh_rep)
                        if self.mesh is not None else None))
        buf = self._acquire_ticket()
        st, row_, tok = self._bgather_p(self.bstaging, self.bsampler,
                                        self.btoks, jnp.int32(row))
        pend = PendingSwap(buf, st, row_, tok)
        self._gather_pending[buf] = pend
        return pend

    def harvest(self, pend: PendingSwap) -> SwappedState:
        """Materialize a draining swap-out into host numpy and return
        its gather-ring ticket.  Blocks only for whatever part of the
        D2H transfer has not already drained (zero when
        ``pend.ready()``).  The PendingSwap's device refs are dropped so
        XLA can recycle the buffer."""
        if self._gather_pending.get(pend.buf) is not pend:
            raise RuntimeError(
                f"harvest of gather buffer {pend.buf} that is not "
                f"draining — double harvest or foreign PendingSwap")
        sw = self._host_state(pend.st, pend.row, pend.tok)
        pend.st = pend.row = pend.tok = None
        del self._gather_pending[pend.buf]
        self._gather_free.append(pend.buf)
        return sw

    # synchronous façade: dispatch + immediate harvest runs the exact
    # same programs on the same operands, so values are bitwise
    # identical to the async path — only the wait moves.
    def gather_slot(self, slot: int) -> SwappedState:
        """Swap a resident request out of slot ``slot``, blocking until
        its host image is materialized (``gather_slot_async`` without
        the overlap)."""
        return self.harvest(self.gather_slot_async(slot))

    def gather_staging(self, buf: int) -> SwappedState:
        """Gather per-prompt ring buffer ``buf``, blocking (see
        ``gather_staging_async``)."""
        return self.harvest(self.gather_staging_async(buf))

    def bgather_row(self, row: int) -> SwappedState:
        """Gather batched staging row ``row``, blocking (see
        ``bgather_row_async``)."""
        return self.harvest(self.bgather_row_async(row))

    def prestage_restore(self, sw: SwappedState):
        """H2D-stage a swapped image for a later ``restore_slot``: the
        device_put (re-sharded under a mesh to the staging/row/replicated
        shardings the scatter expects) happens NOW, the grant-boundary
        scatter later consumes the already-resident triple.  Safe to
        hold across ticks: ``_scatter_p`` donates only the slot buffers
        (args 0–2), never its staging operands, so a prestaged triple
        survives unrelated admits and scatters; a cancelled resume just
        drops the triple."""
        st = self._put(jax.tree.map(jnp.asarray, sw.caches),
                       self._sh_staging)
        row = self._put({k: jnp.asarray(v) for k, v in sw.sampler.items()},
                        self._sh_row)
        tok = self._put(jnp.asarray(sw.token), self._sh_rep)
        return st, row, tok

    def restore_slot(self, slot: int, sw: SwappedState, prestaged=None):
        """Swap-in: put the host-side ``SwappedState`` back on device in
        staging layout (via ``prestage_restore``, or consuming an
        already-prestaged triple) and re-admit it through the EXISTING
        slot-scatter program — the same donated dynamic_update_slice
        every fresh admit takes, so a resumed request's slot residency
        is bitwise what it was at gather time whether or not the put was
        prefetched."""
        st, row, tok = (prestaged if prestaged is not None
                        else self.prestage_restore(sw))
        self.caches, self.sampler, self.tokens = self._scatter_p(
            self.caches, self.sampler, self.tokens, st, row, tok,
            jnp.int32(slot))

    # ------------------------------------------------- speculative decode
    def spec_draft(self, k: int):
        """Propose ``k`` draft tokens per slot: ``lm.decode_steps`` on the
        draft model over throwaway cache/sampler copies (nothing donated —
        the committed draft caches and the sampler stay untouched until
        the verify, so an abandoned draft costs nothing to roll back).
        The proposals stay on device, feeding the verify program without
        a host sync; the draw stream is the slot's own (seed, rid)-folded
        key sequence — the same keys the verify's target sampler will
        consume, which is what collapses coupled rejection sampling to a
        token-equality check.  k = 0 (a verify-only tail tick) returns an
        empty proposal without dispatching."""
        if k == 0:
            return self._put(jnp.zeros((0, self.max_slots), jnp.int32),
                             self._sh_toks2d)
        prog = self._draft_p.get(k)
        if prog is None:
            prog = self._jit(
                lambda dp, t, dc, s, k=k: lm.decode_steps(
                    dp, self.draft_cfg, t, dc, k,
                    sampler=s, sample_fn=sampling.sample)[0],
                in_sh=(self._sh_dparams, self._sh_tokens,
                       self._sh_dcaches, self._sh_sampler),
                out_sh=self._sh_toks2d)
            self._draft_p[k] = prog
        return prog(self.draft_params, self.tokens, self.dcaches,
                    self.sampler)

    def spec_verify(self, k: int, dtoks):
        """Score a pending k-token draft with ``lm.verify_steps`` and
        commit each slot's state exactly through its emitted prefix — the
        single host sync of a speculative tick (up to k+1 tokens per
        slot).  The checkpoint buffers are donated rollback scratch: the
        program's run-ahead finals land in them, so ``caches``/``ckpt``
        (and their draft twins) ping-pong roles every tick and the
        rollback costs no allocation.  Returns host (k+1, S) toks/valid
        in exactly ``decode``'s layout."""
        prog = self._verify_p.get(k)
        if prog is None:
            def _verify(p, dp, dtoks, tokens, caches, ckpt, dcaches,
                        dckpt, samp):
                del ckpt, dckpt     # donated scratch; outputs alias them
                toks, valid, last, com, dcom, run, drun, st = \
                    lm.verify_steps(p, self.cfg, dp, self.draft_cfg,
                                    tokens, dtoks, caches, dcaches, samp,
                                    sampling.sample_where)
                return toks, valid, last, com, run, dcom, drun, st

            prog = self._jit(
                _verify, donate=(3, 4, 5, 6, 7, 8),
                in_sh=(self._sh_params, self._sh_dparams, self._sh_toks2d,
                       self._sh_tokens, self._sh_caches, self._sh_ckpt,
                       self._sh_dcaches, self._sh_dckpt, self._sh_sampler),
                out_sh=((self._sh_toks2d, self._sh_toks2d,
                         self._sh_tokens, self._sh_caches, self._sh_ckpt,
                         self._sh_dcaches, self._sh_dckpt,
                         self._sh_sampler)
                        if self.mesh is not None else None))
            self._verify_p[k] = prog
        (toks, valid, self.tokens, self.caches, self.ckpt, self.dcaches,
         self.dckpt, self.sampler) = prog(
            self.params, self.draft_params, dtoks, self.tokens,
            self.caches, self.ckpt, self.dcaches, self.dckpt,
            self.sampler)
        return np.asarray(toks), np.asarray(valid)

    def draft_prefill_slot(self, slot: int, tokens_1d):
        """Rebuild slot ``slot``'s draft-model state from the request's
        consumed token stream (prompt + all emitted tokens except the
        last, which is the next decode input) — called at every slot
        activation: fresh admit and swap-in alike.  This is why the swap
        image carries no draft state: ONE fixed-shape program (a masked
        (1, n, C) chunk scan from zero state + a donated slot insert)
        reconstructs it, with the same chunk size as the target's staged
        prefill so a self-draft rebuild hits the same chunk boundaries.
        Streams longer than max_len keep the trailing max_len tokens
        (draft quality only — the target never sees this state)."""
        toks = np.asarray(tokens_1d, np.int32).reshape(-1)[-self.max_len:]
        if toks.size == 0:
            raise ValueError("draft_prefill_slot needs >= 1 consumed "
                             "token (prompts are never empty)")
        C, n = self._dchunk, self._dchunks
        flat = np.zeros((n * C,), np.int32)
        flat[:toks.size] = toks
        vls = np.zeros((n,), np.int32)
        full, tail = divmod(toks.size, C)
        vls[:full] = C
        if tail:
            vls[full] = tail
        prog = self._dprefill_p
        if prog is None:
            def _dprefill(dp, t, vl, dcaches, slot):
                c1 = lm.init_caches(self.draft_cfg, 1, self.max_len)
                c1 = lm.prefill_chunk_scan(dp, self.draft_cfg, c1,
                                           tokens=t, valid_lens=vl)
                return jax.tree.map(
                    lambda f, o: jax.lax.dynamic_update_slice_in_dim(
                        f, o.astype(f.dtype), slot, axis=1),
                    dcaches, c1)

            prog = self._jit(
                _dprefill, donate=(3,),
                in_sh=(self._sh_dparams, self._sh_rep, self._sh_rep,
                       self._sh_dcaches, self._sh_rep),
                out_sh=self._sh_dcaches)
            self._dprefill_p = prog
        self.dcaches = prog(self.draft_params,
                            jnp.asarray(flat.reshape(1, n, C)),
                            jnp.asarray(vls), self.dcaches,
                            jnp.int32(slot))

    # ----------------------------------------------------------- metrics
    def compiled_programs(self) -> Dict[str, int]:
        """Live jitted-program cache sizes per family.

        This is the observable the masked planner exists for: with
        ``plan_mode="masked"`` the prefill families stay at ≤
        ``_MAX_SCAN_CHUNKS`` scan shapes + 1 admit shape across *all*
        prompt lengths (and ≤ 2 shapes are ever dispatched for any single
        prompt); the pow2 baseline grows O(log chunk) tail programs on
        top.  Asserted by ``tests/test_ragged_prefill.py`` and reported
        through ``Scheduler.metrics()``."""
        prefill = (len(self._scan_p) + len(self._chunk_p)
                   + len(self._admit_p) + len(self._bscan_p)
                   + len(self._badmit_p))
        spec = (len(self._draft_p) + len(self._verify_p)
                + (1 if self._dprefill_p is not None else 0)
                if self.speculative else 0)
        return {
            "decode": len(self._decode_p),
            "prefill_scan": len(self._scan_p) + len(self._bscan_p),
            "prefill_chunk": len(self._chunk_p),
            "prefill_admit": len(self._admit_p) + len(self._badmit_p),
            "prefill": prefill,
            "speculative": spec,
            # + the slot scatter, + the multi-row scatter once built,
            # + the state-paging gathers once built
            "total": (len(self._decode_p) + prefill + spec + 1
                      + (1 if self._batched_ready else 0)
                      + (1 if self._gather_p is not None else 0)
                      + (1 if self._bgather_p is not None else 0)),
        }

    # ------------------------------------------------------------- ticks
    def decode(self, k: int):
        """One fused k-step decode+sample tick over all slots; the single
        host sync reads the (k, slots) token/validity arrays."""
        prog = self._decode_p.get(k)
        if prog is None:
            prog = self._jit(
                lambda p, t, c, s, k=k: lm.decode_steps(
                    p, self.cfg, t, c, k,
                    sampler=s, sample_fn=sampling.sample),
                donate=(2, 3),
                in_sh=(self._sh_params, self._sh_tokens, self._sh_caches,
                       self._sh_sampler),
                out_sh=((self._sh_toks2d, self._sh_toks2d, self._sh_tokens,
                         self._sh_caches, self._sh_sampler)
                        if self.mesh is not None else None))
            self._decode_p[k] = prog
        toks, valid, self.tokens, self.caches, self.sampler = prog(
            self.params, self.tokens, self.caches, self.sampler)
        return np.asarray(toks), np.asarray(valid)
