"""Checkpointing: atomic, async, auto-resuming — pure numpy/npz (no orbax here).

Layout:  <dir>/step_<n>/shard_<p>.npz + manifest.json
  * leaves flattened with '/'-joined key paths;
  * atomic via write-to-tmp + os.replace (a crashed save never corrupts the
    latest checkpoint — fault-tolerance requirement);
  * async save on a background thread (training continues while the
    previous step serializes);
  * `restore_latest` picks the newest *complete* checkpoint (manifest is
    written last), so partial saves from a killed job are skipped.
In a real multi-host job each process saves the addressable shards of its
arrays; here host_count=1 holds the whole tree.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            # npz can't serialize ml_dtypes (bfloat16 & co): store the raw
            # bits as uint16 and tag the key with the true dtype.
            key = key + f"::{arr.dtype}"
            arr = arr.view(np.uint16)
        flat[key] = arr
    return flat


def save(tree, directory: str, step: int, process_index: int = 0) -> str:
    d = os.path.join(directory, f"step_{step:09d}")
    tmp = d + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, f"shard_{process_index}.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "keys": sorted(flat.keys()),
        "nbytes": int(sum(v.nbytes for v in flat.values())),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.isdir(d):
        shutil.rmtree(d)
    os.replace(tmp, d)                      # atomic publish
    return d


def restore(tree_like, directory: str, step: int, process_index: int = 0):
    d = os.path.join(directory, f"step_{step:09d}")
    with np.load(os.path.join(d, f"shard_{process_index}.npz")) as z:
        flat = dict(z)
    tagged = {}
    for key, arr in flat.items():
        if "::" in key:
            base, dt = key.rsplit("::", 1)
            import ml_dtypes  # noqa: F401 — registers bfloat16 with numpy
            tagged[base] = arr.view(np.dtype(dt))
        else:
            tagged[key] = arr
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, like in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = tagged[key]
        assert arr.shape == like.shape, (key, arr.shape, like.shape)
        leaves.append(arr.astype(like.dtype))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def completed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp0"):
            if os.path.exists(os.path.join(directory, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def latest_step(self) -> Optional[int]:
        steps = completed_steps(self.directory)
        return steps[-1] if steps else None

    def save(self, tree, step: int, blocking: bool = True):
        tree = jax.tree.map(np.asarray, tree)   # snapshot off-device

        def do():
            save(tree, self.directory, step)
            self._gc()

        if blocking:
            do()
        else:
            self.wait()
            self._thread = threading.Thread(target=do, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, tree_like):
        self.wait()
        step = self.latest_step()
        if step is None:
            return None, None
        return restore(tree_like, self.directory, step), step

    def _gc(self):
        steps = completed_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)
