"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core import gdn


def gdn_decode_ref(q, k, v, S, g, beta, *, scale=None, delta_rule=True):
    """Oracle for kernels.gdn_decode. Shapes as in gdn_decode_pallas."""
    B, Hk, d_k = q.shape
    Hv = v.shape[1]
    R = Hv // Hk
    if scale is None:
        scale = (1.0 / math.sqrt(d_k)) if delta_rule else 1.0
    qe, ke = gdn.gva_expand(q, R), gdn.gva_expand(k, R)
    qe = qe.astype(jnp.float32)
    ke = ke.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    Sf = S.astype(jnp.float32)
    if delta_rule:
        fn = lambda q1, k1, v1, S1, g1, b1: gdn.decode_step_fused(
            q1, k1, v1, S1, g1, b1, scale=scale)
        o, S_new = jax.vmap(jax.vmap(fn))(qe, ke, vf, Sf, g, beta)
    else:
        fn = lambda q1, k1, v1, S1, g1: gdn.ssd_decode_step(
            q1, k1, v1, S1, g1, scale=scale)
        o, S_new = jax.vmap(jax.vmap(fn))(qe, ke, vf, Sf, g)
    return o.astype(v.dtype), S_new.astype(S.dtype)


def gdn_prefill_ref(q, k, v, log_g, beta, S0, *, scale=None, delta_rule=True):
    """Oracle for kernels.gdn_prefill: sequential scan per (BH,) row.

    q, k: (BH, T, d_k); v: (BH, T, d_v); log_g, beta: (BH, T);
    S0: (BH, d_k, d_v).
    """
    d_k = q.shape[-1]
    if scale is None:
        scale = (1.0 / math.sqrt(d_k)) if delta_rule else 1.0
    fn = lambda q1, k1, v1, lg1, b1, S1: gdn.prefill_sequential(
        q1.astype(jnp.float32), k1.astype(jnp.float32),
        v1.astype(jnp.float32), lg1.astype(jnp.float32),
        b1.astype(jnp.float32), S1.astype(jnp.float32),
        scale=scale, delta_rule=delta_rule)
    O, S = jax.vmap(fn)(q, k, v, log_g, beta, S0)
    return O.astype(v.dtype), S.astype(S0.dtype)


def attn_decode_ref(q, k_cache, v_cache, length, *, scale=None, window=None):
    """Oracle for kernels.attn_decode: dense softmax with masking."""
    B, Hq, d = q.shape
    _, Hkv, T, _ = k_cache.shape
    Hg = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    qf = q.reshape(B, Hkv, Hg, d).astype(jnp.float32)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    s = scale * jnp.einsum("bhgd,bhtd->bhgt", qf, kf)
    pos = jnp.arange(T)[None, None, None, :]
    valid = pos < length[:, None, None, None]
    if window is not None:
        valid = jnp.logical_and(
            valid, pos >= (length[:, None, None, None] - window))
    s = jnp.where(valid, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bhtd->bhgd", p, vf)
    return o.reshape(B, Hq, d).astype(q.dtype)
