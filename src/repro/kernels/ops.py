"""Public jit'd entry points for the Pallas kernels.

Backend selection: on a real TPU the kernels run compiled (interpret=False);
everywhere else (this CPU container, unit tests) they run in interpret mode,
which executes the same kernel body and BlockSpec pipeline in Python for
bit-faithful validation against ref.py.

The model code (src/repro/models) calls these through ``use_pallas`` config
switches; the multi-pod dry-run lowers the algebraically-identical pure-JAX
paths (see DESIGN.md §2 — XLA fuses the stacked read-pass matmul the same
way, and Pallas TPU kernels cannot be lowered for the CPU dry-run backend).
"""
from __future__ import annotations

import jax

from repro.kernels.gdn_decode import gdn_decode_pallas
from repro.kernels.gdn_prefill import gdn_prefill_pallas
from repro.kernels.attn_decode import attn_decode_pallas
from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def gdn_decode(q, k, v, S, g, beta, *, head_block=8, scale=None,
               delta_rule=True, interpret=None):
    """Fused persistent-state GDN decode step (paper Alg. 2)."""
    if interpret is None:
        interpret = not _on_tpu()
    return gdn_decode_pallas(q, k, v, S, g, beta, head_block=head_block,
                             scale=scale, delta_rule=delta_rule,
                             interpret=interpret)


def gdn_prefill(q, k, v, log_g, beta, S0, *, chunk=64, scale=None,
                delta_rule=True, interpret=None, valid_len=None):
    """Chunkwise prefill, state resident in VMEM across the chunk grid.

    Batched head layout: q,k (B, T, Hk, d_k), v (B, T, Hv, d_v),
    log_g/beta (B, T, Hv), S0 (B, Hv, d_k, d_v).  GVA q/k sharing is done
    via the kernel's row indexing (q/k rows repeated per v-head pair).

    ``valid_len`` (optional, scalar or (B,) int32): ragged sequences padded
    to T — the kernel masks positions >= valid_len so the returned state
    and the valid output rows are exactly those of the unpadded sequence.
    """
    import jax.numpy as jnp
    if interpret is None:
        interpret = not _on_tpu()
    B, T, Hk, d_k = q.shape
    Hv = v.shape[2]
    d_v = v.shape[-1]
    R = Hv // Hk
    # (B, T, H, d) -> (B*H, T, d); repeat q/k rows for GVA
    qh = q.transpose(0, 2, 1, 3)
    kh = k.transpose(0, 2, 1, 3)
    if R > 1:
        qh = jnp.repeat(qh, R, axis=1)
        kh = jnp.repeat(kh, R, axis=1)
    qh = qh.reshape(B * Hv, T, d_k)
    kh = kh.reshape(B * Hv, T, d_k)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hv, T, d_v)
    lgh = log_g.transpose(0, 2, 1).reshape(B * Hv, T)
    bh = beta.transpose(0, 2, 1).reshape(B * Hv, T)
    S0h = S0.reshape(B * Hv, d_k, S0.shape[-1])
    vlh = None
    if valid_len is not None:
        vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (B,))
        vlh = jnp.repeat(vl, Hv, axis=0)               # (B * Hv,)
    O, S = gdn_prefill_pallas(qh, kh, vh, lgh, bh, S0h, vlh, chunk=chunk,
                              scale=scale, delta_rule=delta_rule,
                              interpret=interpret)
    O = O.reshape(B, Hv, T, d_v).transpose(0, 2, 1, 3)
    S = S.reshape(B, Hv, d_k, -1)
    return O, S


def attn_decode(q, k_cache, v_cache, length, *, block_t=256, scale=None,
                window=None, interpret=None):
    """Flash-decode GQA attention against a KV cache."""
    if interpret is None:
        interpret = not _on_tpu()
    return attn_decode_pallas(q, k_cache, v_cache, length, block_t=block_t,
                              scale=scale, window=window, interpret=interpret)


__all__ = ["gdn_decode", "gdn_prefill", "attn_decode", "ref"]
