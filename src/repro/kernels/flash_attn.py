"""Flash-attention training kernel (fwd + bwd) — Pallas TPU.

The train-cell roofline is dominated by attention score traffic: any XLA
formulation materializes O(B·H·T²) bytes of scores/probabilities to HBM
(measured in EXPERIMENTS.md §Perf). This kernel applies the paper's
persistent-on-chip discipline to attention: score blocks live ONLY in VMEM;
HBM traffic is O(B·H·T·d) (q, k, v, o + per-row (m, l) statistics).

Forward: grid (B·Hkv, n_q_blocks, n_kv_blocks), kv sequential; online
softmax accumulators in VMEM scratch; emits o and the logsumexp residuals.
Backward: two kernels — dq (kv sequential per q block) and dk/dv
(q sequential per kv block) — recomputing p = exp(s − lse) blockwise from
the saved statistics, never materializing a (T, T) tensor.

Causal always; optional sliding window (SWA archs). GQA: the G = Hq/Hkv
query heads sharing a kv head are processed in one grid cell (paper's
paired-head datapath, as in gdn_decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _mask(qi, kj, bq, bk, window, valid=None):
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = q_pos >= k_pos
    if window is not None:
        m = jnp.logical_and(m, (q_pos - k_pos) < window)
    if valid is not None:
        # ragged sequence: key positions >= valid_len are padding and must
        # not contribute to any score row (the matching mask to the
        # gdn_prefill kernel's k/v/gate zeroing)
        m = jnp.logical_and(m, k_pos < valid)
    return m


# ----------------------------------------------------------------- forward

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                m_scr, l_scr, acc_scr, *, G, bq, bk, n_kv, scale, window,
                vl_ref=None):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k = k_ref[0].astype(jnp.float32)             # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    mask = _mask(qi, kj, bq, bk, window,
                 None if vl_ref is None else vl_ref[0, 0])
    for g in range(G):                           # unrolled GQA group loop
        q = q_ref[0, g].astype(jnp.float32)      # (bq, hd)
        s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[g][:, None]               # (bq, 1)
        l_prev = l_scr[g][:, None]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[g] = corr * acc_scr[g] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[g] = m_new[:, 0]
        l_scr[g] = l_new[:, 0]

    @pl.when(kj == n_kv - 1)
    def _():
        for g in range(G):
            l = jnp.maximum(l_scr[g][:, None], 1e-30)
            o_ref[0, g] = (acc_scr[g] / l).astype(o_ref.dtype)
            m_ref[0, g] = m_scr[g]
            l_ref[0, g] = l_scr[g]


def _fwd_kernel_ragged(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                       m_scr, l_scr, acc_scr, **kw):
    _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, m_scr, l_scr,
                acc_scr, vl_ref=vl_ref, **kw)


def _len_spec(valid_len, BH, in_specs, args):
    """Prepend the (BH, 1) per-sequence valid-length input (ragged calls)."""
    spec = pl.BlockSpec((1, 1), lambda b, i, j: (b, 0))
    return ([spec] + in_specs,
            (valid_len.reshape(BH, 1).astype(jnp.int32),) + args)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "scale",
                                             "window", "interpret"))
def flash_fwd(q, k, v, valid_len=None, *, block_q=512, block_kv=512,
              scale=None, window=None, interpret=False):
    """q: (BH, G, T, hd); k, v: (BH, T, hd) -> o, m, l.

    ``valid_len`` (optional, (BH,) int32): key positions >= valid_len are
    padding and masked out of every score row; output rows at padded query
    positions are garbage (callers must ignore / zero their cotangents).
    """
    BH, G, T, hd = q.shape
    bq, bk = min(block_q, T), min(block_kv, T)
    assert T % bq == 0 and T % bk == 0
    nq, nkv = T // bq, T // bk
    if scale is None:
        scale = hd ** -0.5
    kern = functools.partial(
        _fwd_kernel if valid_len is None else _fwd_kernel_ragged,
        G=G, bq=bq, bk=bk, n_kv=nkv, scale=scale, window=window)
    in_specs = [
        pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
    ]
    args = (q, k, v)
    if valid_len is not None:
        in_specs, args = _len_spec(valid_len, BH, in_specs, args)
    o, m, l = pl.pallas_call(
        kern,
        grid=(BH, nq, nkv),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
            pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((BH, G, T), jnp.float32),
            jax.ShapeDtypeStruct((BH, G, T), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name=f"flash_fwd_bq{bq}",
    )(*args)
    return o, m, l


# ----------------------------------------------------------------- backward

def _p_block(q, k, m, l, qi, kj, bq, bk, scale, window, valid=None):
    s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = jnp.where(_mask(qi, kj, bq, bk, window, valid), s, NEG_INF)
    return jnp.exp(s - m[:, None]) / jnp.maximum(l, 1e-30)[:, None]


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dlt_ref, dq_ref,
               dq_scr, *, G, bq, bk, n_kv, scale, window, vl_ref=None):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    valid = None if vl_ref is None else vl_ref[0, 0]
    for g in range(G):
        q = q_ref[0, g].astype(jnp.float32)
        do = do_ref[0, g].astype(jnp.float32)
        p = _p_block(q, k, m_ref[0, g], l_ref[0, g], qi, kj, bq, bk,
                     scale, window, valid)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_ref[0, g][:, None])
        dq_scr[g] += scale * jnp.dot(ds, k,
                                     preferred_element_type=jnp.float32)

    @pl.when(kj == n_kv - 1)
    def _():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dlt_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, G, bq, bk, n_q, scale,
                window, vl_ref=None):
    kj, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    valid = None if vl_ref is None else vl_ref[0, 0]
    for g in range(G):
        q = q_ref[0, g].astype(jnp.float32)
        do = do_ref[0, g].astype(jnp.float32)
        p = _p_block(q, k, m_ref[0, g], l_ref[0, g], qi, kj, bq, bk,
                     scale, window, valid)
        dv_scr[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - dlt_ref[0, g][:, None])
        dk_scr[...] += scale * jnp.dot(ds.T, q,
                                       preferred_element_type=jnp.float32)

    @pl.when(qi == n_q - 1)
    def _():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _dq_kernel_ragged(vl_ref, q_ref, k_ref, v_ref, do_ref, m_ref, l_ref,
                      dlt_ref, dq_ref, dq_scr, **kw):
    _dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dlt_ref, dq_ref,
               dq_scr, vl_ref=vl_ref, **kw)


def _dkv_kernel_ragged(vl_ref, q_ref, k_ref, v_ref, do_ref, m_ref, l_ref,
                       dlt_ref, dk_ref, dv_ref, dk_scr, dv_scr, **kw):
    _dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, dlt_ref, dk_ref,
                dv_ref, dk_scr, dv_scr, vl_ref=vl_ref, **kw)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "scale",
                                             "window", "interpret"))
def flash_bwd(q, k, v, o, m, l, do, valid_len=None, *, block_q=512,
              block_kv=512, scale=None, window=None, interpret=False):
    BH, G, T, hd = q.shape
    bq, bk = min(block_q, T), min(block_kv, T)
    nq, nkv = T // bq, T // bk
    if scale is None:
        scale = hd ** -0.5
    # delta = rowsum(do * o) — cheap, pure XLA
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), -1)

    dq_specs = [
        pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
        pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
        pl.BlockSpec((1, G, bq), lambda b, i, j: (b, 0, i)),
    ]
    dq_args = (q, k, v, do, m, l, delta)
    if valid_len is not None:
        dq_specs, dq_args = _len_spec(valid_len, BH, dq_specs, dq_args)
    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel if valid_len is None else _dq_kernel_ragged,
            G=G, bq=bq, bk=bk, n_kv=nkv, scale=scale, window=window),
        grid=(BH, nq, nkv),
        in_specs=dq_specs,
        out_specs=pl.BlockSpec((1, G, bq, hd), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((G, bq, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="flash_bwd_dq",
    )(*dq_args)

    dkv_specs = [
        pl.BlockSpec((1, G, bq, hd), lambda b, j, i: (b, 0, i, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        pl.BlockSpec((1, G, bq, hd), lambda b, j, i: (b, 0, i, 0)),
        pl.BlockSpec((1, G, bq), lambda b, j, i: (b, 0, i)),
        pl.BlockSpec((1, G, bq), lambda b, j, i: (b, 0, i)),
        pl.BlockSpec((1, G, bq), lambda b, j, i: (b, 0, i)),
    ]
    dkv_args = (q, k, v, do, m, l, delta)
    if valid_len is not None:
        dkv_specs, dkv_args = _len_spec(valid_len, BH, dkv_specs, dkv_args)
    dk, dv = pl.pallas_call(
        functools.partial(
            _dkv_kernel if valid_len is None else _dkv_kernel_ragged,
            G=G, bq=bq, bk=bk, n_q=nq, scale=scale, window=window),
        grid=(BH, nkv, nq),
        in_specs=dkv_specs,
        out_specs=[
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, j, i: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(k.shape, jnp.float32),
            jax.ShapeDtypeStruct(v.shape, jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name="flash_bwd_dkv",
    )(*dkv_args)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


# ----------------------------------------------------------------- custom vjp

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, block_q=512, block_kv=512, window=None,
                    interpret=False, valid_len=None):
    """Causal (optionally windowed) GQA flash attention.

    q: (B, T, Hq, hd); k, v: (B, T, Hkv, hd). Returns (B, T, Hq, hd).
    Scores never touch HBM; residuals are o + (m, l) per row.

    ``valid_len`` (optional, (B,) int32) marks ragged sequences padded to
    T: key positions >= valid_len are masked out of every score row (and
    out of the dk/dv accumulations), so padded rows never leak into valid
    outputs or gradients.  Output rows and dq rows at padded query
    positions are garbage — mask them (and their loss terms) upstream.
    """
    o, _, _ = _flash_fwd_shaped(q, k, v, valid_len, block_q, block_kv,
                                window, interpret)
    return o


def _reshape_in(q, k, v):
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hkv, G, T, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T, hd)
    return qh, kh, vh, (B, T, Hq, Hkv, hd)


def _len_per_bh(valid_len, Hkv):
    """(B,) per-sequence lengths -> (B * Hkv,) per-grid-row lengths."""
    if valid_len is None:
        return None
    return jnp.repeat(valid_len.astype(jnp.int32), Hkv, axis=0)


def _flash_fwd_shaped(q, k, v, valid_len, block_q, block_kv, window,
                      interpret):
    qh, kh, vh, (B, T, Hq, Hkv, hd) = _reshape_in(q, k, v)
    o, m, l = flash_fwd(qh, kh, vh, _len_per_bh(valid_len, Hkv),
                        block_q=block_q, block_kv=block_kv,
                        window=window, interpret=interpret)
    o_out = o.reshape(B, Hkv, Hq // Hkv, T, hd).reshape(
        B, Hq, T, hd).transpose(0, 2, 1, 3)
    return o_out, m, l


def _fwd_rule(q, k, v, block_q, block_kv, window, interpret,
              valid_len=None):
    o, m, l = _flash_fwd_shaped(q, k, v, valid_len, block_q, block_kv,
                                window, interpret)
    return o, (q, k, v, o, m, l, valid_len)


def _bwd_rule(block_q, block_kv, window, interpret, res, do):
    q, k, v, o, m, l, valid_len = res
    qh, kh, vh, (B, T, Hq, Hkv, hd) = _reshape_in(q, k, v)
    G = Hq // Hkv
    oh = o.transpose(0, 2, 1, 3).reshape(B * Hkv, G, T, hd)
    doh = do.transpose(0, 2, 1, 3).reshape(B * Hkv, G, T, hd)
    dq, dk, dv = flash_bwd(qh, kh, vh, oh, m, l, doh,
                           _len_per_bh(valid_len, Hkv), block_q=block_q,
                           block_kv=block_kv, window=window,
                           interpret=interpret)
    dq_out = dq.reshape(B, Hq, T, hd).transpose(0, 2, 1, 3)
    dk_out = dk.reshape(B, Hkv, T, hd).transpose(0, 2, 1, 3)
    dv_out = dv.reshape(B, Hkv, T, hd).transpose(0, 2, 1, 3)
    if valid_len is None:
        return dq_out, dk_out, dv_out, None
    # int32 primal: the only well-typed cotangent is float0 zeros
    return dq_out, dk_out, dv_out, np.zeros(valid_len.shape,
                                            jax.dtypes.float0)


flash_attention.defvjp(_fwd_rule, _bwd_rule)
