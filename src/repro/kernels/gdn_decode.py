"""Fused persistent-state GDN decode kernel (paper Alg. 2, TPU-native).

One `pallas_call` per token performs, for every value-head:

  read pass : one traversal of the (d_k, d_v) state block in VMEM computing
              BOTH the retrieval r = S^T k and the partial output S^T q as a
              single stacked (2, d_k) @ (d_k, d_v) MXU matmul
  write pass: S <- g*S + k (beta (v - r))^T  written back through the same
              VMEM block, aliased in-place onto the input state buffer
              (``input_output_aliases``) — the TPU analogue of the paper's
              persistent BRAM state: the state is touched exactly once each
              way per token and never copied.

Grid: (batch, h_v / head_block).  ``head_block`` is the direct analogue of
the paper's H_iter design knob (v-heads per dataflow iteration) and is swept
in the benchmarks.  GVA: q/k blocks hold head_block // n_rep shared heads and
are broadcast to their value-head pair inside the kernel (the paper's
paired-head datapath).

``delta_rule=False`` degenerates to the Mamba-2 / SSD decode update
(S <- g*S + k v^T, o = S^T q) and is used by the mamba2 architecture.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _kernel(q_ref, k_ref, v_ref, s_ref, g_ref, b_ref, o_ref, s_out_ref, *,
            head_block: int, n_rep: int, scale: float, delta_rule: bool):
    for h in range(head_block):                    # fully unrolled head loop
        hk = h // n_rep                            # shared GVA q/k head
        S = s_ref[0, h].astype(jnp.float32)        # (d_k, d_v) — read pass
        kk = k_ref[0, hk:hk + 1].astype(jnp.float32)   # (1, d_k)
        qq = q_ref[0, hk:hk + 1].astype(jnp.float32)   # (1, d_k)
        g = g_ref[0, h].astype(jnp.float32)
        kq = jnp.concatenate([kk, qq], axis=0)     # (2, d_k)
        rr = jnp.dot(kq, S, preferred_element_type=jnp.float32)  # (2, d_v)
        r, sq = rr[0:1], rr[1:2]                   # (1, d_v) each
        if delta_rule:
            beta = b_ref[0, h].astype(jnp.float32)
            vv = v_ref[0, h:h + 1].astype(jnp.float32)      # (1, d_v)
            dv = beta * (vv - r)                   # delta correction
            alpha = jnp.sum(kk * qq)               # q^T k
            o = scale * (g * sq + alpha * dv)      # fused output correction
        else:                                      # SSD / mamba2 path
            vv = v_ref[0, h:h + 1].astype(jnp.float32)
            dv = vv
            alpha = jnp.sum(kk * qq)
            o = scale * (g * sq + alpha * dv)
        S_new = g * S + jnp.dot(kq[0:1].T, dv,
                                preferred_element_type=jnp.float32)
        o_ref[0, h:h + 1] = o.astype(o_ref.dtype)
        s_out_ref[0, h] = S_new.astype(s_out_ref.dtype)  # write pass (aliased)


@functools.partial(
    jax.jit,
    static_argnames=("head_block", "scale", "delta_rule", "interpret"))
def gdn_decode_pallas(q, k, v, S, g, beta, *, head_block: int = 8,
                      scale: float | None = None, delta_rule: bool = True,
                      interpret: bool = False):
    """Fused GDN decode step.

    q, k : (B, Hk, d_k)       v: (B, Hv, d_v)
    S    : (B, Hv, d_k, d_v)  g, beta: (B, Hv)
    Returns (o, S_new) with o: (B, Hv, d_v); S_new aliases S's buffer.
    """
    B, Hk, d_k = q.shape
    _, Hv, d_v = v.shape
    n_rep = Hv // Hk
    assert Hv % Hk == 0
    hb = min(head_block, Hv)
    assert Hv % hb == 0 and hb % n_rep == 0, (Hv, hb, n_rep)
    hbk = hb // n_rep                              # q/k heads per block
    if scale is None:
        scale = (1.0 / (d_k ** 0.5)) if delta_rule else 1.0

    grid = (B, Hv // hb)
    kern = functools.partial(_kernel, head_block=hb, n_rep=n_rep,
                             scale=scale, delta_rule=delta_rule)
    out_shape = [
        jax.ShapeDtypeStruct((B, Hv, d_v), v.dtype),
        jax.ShapeDtypeStruct(S.shape, S.dtype),
    ]
    in_specs = [
        pl.BlockSpec((1, hbk, d_k), lambda b, i: (b, i, 0)),      # q
        pl.BlockSpec((1, hbk, d_k), lambda b, i: (b, i, 0)),      # k
        pl.BlockSpec((1, hb, d_v), lambda b, i: (b, i, 0)),       # v
        pl.BlockSpec((1, hb, d_k, d_v), lambda b, i: (b, i, 0, 0)),  # S
        pl.BlockSpec((1, hb), lambda b, i: (b, i)),               # g
        pl.BlockSpec((1, hb), lambda b, i: (b, i)),               # beta
    ]
    out_specs = [
        pl.BlockSpec((1, hb, d_v), lambda b, i: (b, i, 0)),
        pl.BlockSpec((1, hb, d_k, d_v), lambda b, i: (b, i, 0, 0)),
    ]
    o, S_new = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={3: 1},               # S updated in place
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL)),
        interpret=interpret,
        name=f"gdn_decode_hb{hb}",
    )(q, k, v, S, g, beta)
    return o, S_new
