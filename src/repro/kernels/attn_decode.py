"""Flash-decode GQA attention kernel (for the attention archs / hybrid layers).

Embodies the same one-pass discipline the paper applies to recurrent state,
applied to the KV cache: each decode step makes exactly one streaming pass
over K and V with online softmax, accumulating in VMEM scratch.  Grid is
(batch, kv_heads, kv_blocks) with the kv-block dimension sequential; the
group of Hg = Hq // Hkv query heads sharing a kv head is processed together
(GQA analogue of the paper's GVA paired-head datapath).

Supports a per-sequence valid ``length`` (for batched serving with ragged
contexts) and an optional sliding ``window`` (SWA archs: h2o-danube,
mixtral, recurrentgemma local attention) via position masking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_t: int, n_blocks: int, scale: float, window: int | None):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)               # (Hg, d)
    k = k_ref[0, 0].astype(jnp.float32)               # (Bt, d)
    v = v_ref[0, 0].astype(jnp.float32)               # (Bt, d)
    length = len_ref[0, 0]                            # scalar int32

    s = scale * jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Hg, Bt)
    pos = t * block_t + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # occupancy mask owned by the kernel: slot t holds a token iff
    # t < length (linear phase) or always (rolling phase, length > T) —
    # callers pass the raw token count, the kernel clamps to the buffer
    total = n_blocks * block_t
    valid = pos < jnp.minimum(length, total)
    if window is not None:
        # window masking must compare *absolute positions*: slot `pos`
        # holds the largest p < length with p ≡ pos (mod T), which is
        # `pos` itself only in the linear phase — in the rolling phase
        # the newest tokens wrap onto the lowest slots
        p_abs = (length - 1) - jnp.mod(length - 1 - pos, total)
        valid = jnp.logical_and(valid, p_abs >= length - window)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_scr[...]                               # (Hg, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = corr * acc_scr[...] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(t == n_blocks - 1)
    def _():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_t", "scale", "window", "interpret"))
def attn_decode_pallas(q, k_cache, v_cache, length, *, block_t: int = 256,
                       scale: float | None = None, window: int | None = None,
                       interpret: bool = False):
    """One-token GQA attention against a KV cache.

    q        : (B, Hq, d)
    k_cache  : (B, Hkv, T, d);  v_cache same
    length   : (B,) int32 — valid tokens seen so far per sequence (may
               exceed T for rolling caches: the kernel clamps the
               occupancy mask to the buffer itself, so callers never
               pre-clamp; with masked ragged prefill upstream this is
               the count of *real* tokens, padding excluded)
    Returns o: (B, Hq, d).
    """
    B, Hq, d = q.shape
    _, Hkv, T, _ = k_cache.shape
    Hg = Hq // Hkv
    assert Hq % Hkv == 0
    bt = min(block_t, T)
    assert T % bt == 0
    n_blocks = T // bt
    if scale is None:
        scale = 1.0 / (d ** 0.5)

    qg = q.reshape(B, Hkv, Hg, d)
    len2d = length.reshape(B, 1).astype(jnp.int32)

    kern = functools.partial(_kernel, block_t=bt, n_blocks=n_blocks,
                             scale=scale, window=window)
    grid = (B, Hkv, n_blocks)
    o = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, t: (b, 0)),          # length
            pl.BlockSpec((1, 1, Hg, d), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt, d), lambda b, h, t: (b, h, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hg, d), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, Hg, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, 1), jnp.float32),
            pltpu.VMEM((Hg, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.PARALLEL,
                                 pltpu.ARBITRARY)),
        interpret=interpret,
        name=f"attn_decode_bt{bt}",
    )(len2d, qg, k_cache, v_cache)
    return o.reshape(B, Hq, d)
