"""Pallas TPU API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; support
both so the kernels import on whichever jax the container bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
