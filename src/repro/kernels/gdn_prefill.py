"""Chunkwise GDN prefill kernel — state persistent in VMEM across chunks.

This is the *strongest* TPU analogue of the paper's persistent BRAM state:
one ``pallas_call`` processes the whole sequence for a (batch, v-head) pair,
carrying the (d_k, d_v) state in a VMEM scratch buffer across the sequential
chunk grid dimension.  State touches HBM exactly twice per sequence (initial
load, final store) — zero intermediate round-trips, vs. one round-trip per
chunk for a chunk-at-a-time GPU kernel.

Math (gated UT/WY transform, identical to ``repro.core.gdn.prefill_chunkwise``):
  (I + A) U = beta * (V - gamma_prev * (K @ S0)),   A strictly lower
  O  = scale * (gamma * (Q @ S0) + M @ U)
  S' = gamma_C * S0 + (exp(L_C - L) * K)^T @ U

The triangular inverse (I + A)^{-1} is computed *exactly* with the nilpotent
doubling identity  sum_i (-A)^i = prod_j (I + (-A)^{2^j})  — log2(C) MXU
matmuls, no sequential forward substitution (TPU-friendly; a row-by-row
solve would serialize on the VPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams


def _nilpotent_inv_apply(A, rhs, chunk):
    """Compute (I + A)^{-1} @ rhs for strictly-lower-triangular A, exactly."""
    X = rhs
    M = -A
    steps = max(1, (chunk - 1).bit_length())       # 2^steps >= chunk
    for _ in range(steps):
        X = X + jnp.dot(M, X, preferred_element_type=jnp.float32)
        M = jnp.dot(M, M, preferred_element_type=jnp.float32)
    return X


def _kernel(q_ref, k_ref, v_ref, lg_ref, b_ref, s0_ref, o_ref, s_out_ref,
            s_scr, *, chunk: int, scale: float, delta_rule: bool,
            n_chunks: int, vl_ref=None):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    S0 = s_scr[...]                                   # (d_k, d_v) resident
    q = q_ref[0].astype(jnp.float32)                  # (C, d_k)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                  # (C, d_v)
    lg = lg_ref[0].astype(jnp.float32)                # (C,) via (1, C) block
    b = b_ref[0].astype(jnp.float32)                  # (C,)
    if vl_ref is not None:
        # ragged sequence: positions >= valid_len are padding.  Zeroing the
        # k/v/beta columns and the log-gate contribution makes every padded
        # token an exact no-op on the state (g=1, rank-1 update 0) and on
        # every valid output row (their M/A columns vanish), so a fixed-size
        # masked chunk is provably the same program as a right-sized one.
        pos = c * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
        vm = pos < vl_ref[0, 0]                       # (C, 1)
        k = jnp.where(vm, k, 0.0)
        v = jnp.where(vm, v, 0.0)
        lg = jnp.where(vm[:, 0], lg, 0.0)
        b = jnp.where(vm[:, 0], b, 0.0)
    L = jnp.cumsum(lg)                                # (C,)
    L_prev = L - lg
    gamma = jnp.exp(L)[:, None]
    gamma_prev = jnp.exp(L_prev)[:, None]

    row = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)

    qk = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    decayM = jnp.exp(L[:, None] - L[None, :])
    M = jnp.where(row >= col, decayM * qk, 0.0)       # inclusive lower

    if delta_rule:
        beta = b[:, None]                             # (C, 1)
        kk = jnp.dot(k, k.T, preferred_element_type=jnp.float32)
        decayA = jnp.exp(L_prev[:, None] - L[None, :])
        A = jnp.where(row > col, beta * decayA * kk, 0.0)
        rhs = beta * (v - gamma_prev *
                      jnp.dot(k, S0, preferred_element_type=jnp.float32))
        U = _nilpotent_inv_apply(A, rhs, chunk)
    else:                                             # SSD / mamba2
        U = v

    O = scale * (gamma * jnp.dot(q, S0, preferred_element_type=jnp.float32)
                 + jnp.dot(M, U, preferred_element_type=jnp.float32))
    o_ref[0] = O.astype(o_ref.dtype)

    w = jnp.exp(L[-1] - L)[:, None]
    S_new = jnp.exp(L[-1]) * S0 + jnp.dot((w * k).T, U,
                                          preferred_element_type=jnp.float32)
    s_scr[...] = S_new

    @pl.when(c == n_chunks - 1)
    def _():
        s_out_ref[0] = S_new.astype(s_out_ref.dtype)


def _kernel_ragged(vl_ref, q_ref, k_ref, v_ref, lg_ref, b_ref, s0_ref,
                   o_ref, s_out_ref, s_scr, **kw):
    _kernel(q_ref, k_ref, v_ref, lg_ref, b_ref, s0_ref, o_ref, s_out_ref,
            s_scr, vl_ref=vl_ref, **kw)


@functools.partial(
    jax.jit,
    static_argnames=("chunk", "scale", "delta_rule", "interpret"))
def gdn_prefill_pallas(q, k, v, log_g, beta, S0, valid_len=None, *,
                       chunk: int = 64, scale: float | None = None,
                       delta_rule: bool = True, interpret: bool = False):
    """Chunkwise prefill over full sequences, state resident in VMEM.

    q, k : (BH, T, d_k) with BH = batch * h_v (q/k pre-grouped per v-head by
           the caller index map — see ops.gdn_prefill for the GVA mapping)
    v    : (BH, T, d_v);  log_g, beta: (BH, T);  S0: (BH, d_k, d_v)
    valid_len : optional (BH,) int32 — per-sequence count of real tokens;
           positions >= valid_len are padding, masked *inside* the kernel so
           the final state and the valid output rows are exactly those of an
           unpadded sequence (rows past valid_len are garbage — ignore them).
    Returns O: (BH, T, d_v), S_final: (BH, d_k, d_v).
    """
    BH, T, d_k = q.shape
    d_v = v.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    n_chunks = T // chunk
    if scale is None:
        scale = (1.0 / (d_k ** 0.5)) if delta_rule else 1.0

    kern = functools.partial(_kernel, chunk=chunk, scale=scale,
                             delta_rule=delta_rule, n_chunks=n_chunks)
    grid = (BH, n_chunks)
    out_shape = [
        jax.ShapeDtypeStruct((BH, T, d_v), v.dtype),
        jax.ShapeDtypeStruct((BH, d_k, d_v), S0.dtype),
    ]
    in_specs = [
        pl.BlockSpec((1, chunk, d_k), lambda b, c: (b, c, 0)),   # q
        pl.BlockSpec((1, chunk, d_k), lambda b, c: (b, c, 0)),   # k
        pl.BlockSpec((1, chunk, d_v), lambda b, c: (b, c, 0)),   # v
        pl.BlockSpec((1, chunk), lambda b, c: (b, c)),           # log_g
        pl.BlockSpec((1, chunk), lambda b, c: (b, c)),           # beta
        pl.BlockSpec((1, d_k, d_v), lambda b, c: (b, 0, 0)),     # S0
    ]
    args = (q, k, v, log_g, beta, S0)
    if valid_len is not None:
        kern = functools.partial(_kernel_ragged, chunk=chunk, scale=scale,
                                 delta_rule=delta_rule, n_chunks=n_chunks)
        in_specs = [pl.BlockSpec((1, 1), lambda b, c: (b, 0))] + in_specs
        args = (valid_len.reshape(BH, 1).astype(jnp.int32),) + args
    out_specs = [
        pl.BlockSpec((1, chunk, d_v), lambda b, c: (b, c, 0)),
        pl.BlockSpec((1, d_k, d_v), lambda b, c: (b, 0, 0)),
    ]
    O, S_fin = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((d_k, d_v), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY)),
        interpret=interpret,
        name=f"gdn_prefill_c{chunk}",
    )(*args)
    return O, S_fin
