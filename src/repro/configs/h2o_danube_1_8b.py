"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; hf]  24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000, head_dim=80, SWA window 4096.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-1.8b",
    family="dense",
    vocab=32000,
    d_model=2560,
    n_layers=24,
    pattern=("swa",),
    ffn="dense",
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    window=4096,
    subquadratic=True,   # SWA: O(window) KV -> long_500k decode runs
    notes="SWA bounds the KV cache to the 4096-token window: long_500k "
          "decode runs with an O(1)-in-seq-len rolling cache.",
)
