"""qwen3-next-gdn — the paper's own architecture (Qwen3-Next-style hybrid).

3:1 Gated DeltaNet : full attention (paper Fig. 2), with the GDN layer at
exactly the paper's configuration: h_q = h_k = 16, h_v = 32 (2:1 GVA),
head_dim d = 128 => 32 state matrices of 128x128 = 2 MB/layer fp32.
48 layers = 12 x (gdn, gdn, gdn, attn), d_model=2048.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-next-gdn",
    family="hybrid",
    vocab=32000,
    d_model=2048,
    n_layers=48,
    pattern=("gdn", "gdn", "gdn", "attn"),
    ffn="dense",
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=5504,
    gdn_k_heads=16,
    gdn_v_heads=32,
    gdn_head_dim=128,
    subquadratic=True,
    notes="Paper's own config: GDN decode is the dominant per-token "
          "primitive (36 of 48 layers). The 12 full-attention layers make "
          "long_500k bounded only by their KV; we run long_500k with the "
          "full-attn KV at 500k sharded over the model axis (36 GDN layers "
          "are O(1)).",
)
