"""mixtral-8x7b — MoE 8 experts top-2, GQA, sliding-window attention.

[arXiv:2401.04088; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, head_dim=128, 8e top-2, SWA window 4096.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    vocab=32000,
    d_model=4096,
    n_layers=32,
    pattern=("swa",),
    ffn="moe",
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    moe_experts=8,
    moe_top_k=2,
    moe_group_size=1024,
    window=4096,
    rope_theta=1e6,
    subquadratic=True,   # SWA per assignment -> long_500k runs
    notes="8-way EP on the model axis (2-way TP inside each expert). "
          "SWA window bounds the KV cache for long_500k decode.",
)
