"""llava-next-34b — VLM backbone (anyres tiling frontend is a stub).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000, head_dim=128.
Vision frontend: input_specs() provides precomputed patch embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    vocab=64000,
    d_model=7168,
    n_layers=60,
    pattern=("attn",),
    ffn="dense",
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    n_heads_pad=64,      # GQA group 7 -> 8 (one pad head per kv group;
                         # exact via ArchConfig.head_mask)
    d_ff=20480,
    rope_theta=1e6,
    frontend_stub="vision",
    subquadratic=False,
    notes="VLM backbone only; anyres patch embeddings stubbed via embeds "
          "input. long_500k skipped (pure full attention).",
)
