"""arctic-480b — 128-expert top-2 MoE with a parallel dense-residual MLP.

[hf:Snowflake/snowflake-arctic-base; hf]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 (per expert) vocab=32000, head_dim=128, 128e top-2 + dense residual.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    vocab=32000,
    d_model=7168,
    n_layers=35,
    pattern=("attn",),
    ffn="moe+dense",
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    d_ff_dense=4864,
    moe_experts=128,
    moe_top_k=2,
    moe_group_size=1024,
    subquadratic=False,
    notes="Largest assigned arch (~0.5T params): requires FSDP sharding of "
          "params/optimizer over the data axis on top of 16-way EP+TP, and "
          "factored/bf16 optimizer state to fit v5e HBM. long_500k skipped "
          "(full attention).",
)
