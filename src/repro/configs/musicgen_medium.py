"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf]  48L d_model=1536 24H (kv=24, MHA) d_ff=6144
vocab=2048, head_dim=64.  EnCodec frontend is a stub: input_specs()
provides precomputed frame embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    vocab=2048,
    d_model=1536,
    n_layers=48,
    pattern=("attn",),
    ffn="dense",
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    n_heads_pad=32,      # TP head padding (exact; ArchConfig.head_mask)
    n_kv_heads_pad=32,
    d_ff=6144,
    frontend_stub="audio",
    subquadratic=False,
    notes="Audio backbone only; EnCodec codebook interleaving stubbed via "
          "embeds input. long_500k skipped (full attention).",
)
