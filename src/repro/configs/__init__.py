"""Architecture registry: 10 assigned archs + the paper's own (qwen3-next GDN)."""
from __future__ import annotations

from repro.configs.base import (ArchConfig, ServingTopology, ShapeConfig,
                                SHAPES, shape_applicable)
from repro.configs.llava_next_34b import CONFIG as llava_next_34b
from repro.configs.minicpm_2b import CONFIG as minicpm_2b
from repro.configs.minitron_8b import CONFIG as minitron_8b
from repro.configs.yi_9b import CONFIG as yi_9b
from repro.configs.h2o_danube_1_8b import CONFIG as h2o_danube_1_8b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.arctic_480b import CONFIG as arctic_480b
from repro.configs.musicgen_medium import CONFIG as musicgen_medium
from repro.configs.mamba2_1_3b import CONFIG as mamba2_1_3b
from repro.configs.recurrentgemma_2b import CONFIG as recurrentgemma_2b
from repro.configs.qwen3_next_gdn import CONFIG as qwen3_next_gdn

ARCHS = {
    c.name: c for c in [
        llava_next_34b, minicpm_2b, minitron_8b, yi_9b, h2o_danube_1_8b,
        mixtral_8x7b, arctic_480b, musicgen_medium, mamba2_1_3b,
        recurrentgemma_2b, qwen3_next_gdn,
    ]
}

ASSIGNED = [n for n in ARCHS if n != "qwen3-next-gdn"]


def get_arch(name: str) -> ArchConfig:
    key = name.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[key]


__all__ = ["ArchConfig", "ServingTopology", "ShapeConfig", "SHAPES",
           "ARCHS", "ASSIGNED", "get_arch", "shape_applicable"]
