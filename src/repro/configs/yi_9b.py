"""yi-9b — llama-arch GQA dense.

[arXiv:2403.04652; hf]  48L d_model=4096 32H (GQA kv=4) d_ff=11008
vocab=64000, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    vocab=64000,
    d_model=4096,
    n_layers=48,
    pattern=("attn",),
    ffn="dense",
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    subquadratic=False,
    notes="kv=4 < model-axis size: decode KV cache shards over the cache "
          "length dim instead of heads. long_500k skipped (full attention).",
)
