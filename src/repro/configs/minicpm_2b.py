"""minicpm-2b — dense llama-like, MHA, tied embeddings, WSD schedule.

[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753.
head_dim = 2304 / 36 = 64.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    vocab=122753,
    d_model=2304,
    n_layers=40,
    pattern=("attn",),
    ffn="dense",
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    n_heads_pad=48,      # TP head padding to the 16-wide model axis (exact
    n_kv_heads_pad=48,   # via output masking — see ArchConfig.head_mask)
    d_ff=5760,
    tie_embeddings=True,
    subquadratic=False,
    notes="Trains with the WSD (warmup-stable-decay) schedule "
          "(repro.optim.schedules.wsd). long_500k skipped (full attention).",
)
