"""recurrentgemma-2b — RG-LRU + local attention hybrid (1 attn : 2 rec).

[arXiv:2402.19427; hf]  26L d_model=2560 10H (GQA kv=1, MQA) d_ff=7680
vocab=256000, head_dim=256, local attention window 2048.
26 layers = 8 x (rec, rec, swa) + (rec, rec).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    vocab=256000,
    d_model=2560,
    n_layers=26,
    pattern=("rglru", "rglru", "swa"),
    ffn="dense",
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    n_heads_pad=16,      # MQA: q heads padded to the model axis (exact)
    d_ff=7680,
    window=2048,
    rglru_width=2560,
    subquadratic=True,
    notes="Vector (diagonal) recurrent state: persistence applies, the "
          "matrix-state MXU datapath does not (DESIGN.md "
          "§Arch-applicability). long_500k runs (O(1) state + windowed KV).",
)
