"""Architecture config schema + shape definitions (assigned cells).

Every assigned architecture is an ``ArchConfig``; the unified hybrid LM in
``repro.models.lm`` consumes it directly.  ``reduced()`` produces the small
same-family config used by CPU smoke tests; full configs are only ever
lowered via ShapeDtypeStructs in the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    vocab: int
    d_model: int
    n_layers: int
    pattern: Tuple[str, ...]          # mixer kinds, cycled over layers
    ffn: str = "dense"                # dense | moe | moe+dense | none
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    # TP head padding: heads are padded (with zero weights + a static output
    # mask — mathematically exact) up to a multiple of the model axis so
    # attention shards by head instead of head_dim (head_dim sharding makes
    # every score block an all-reduce — measured 687 GB/device on
    # recurrentgemma prefill_32k, EXPERIMENTS.md §Perf i5). 0 = no padding.
    n_heads_pad: int = 0
    n_kv_heads_pad: int = 0
    window: Optional[int] = None      # sliding-window size for "swa" mixers
    rope_theta: float = 10000.0
    # ffn
    d_ff: int = 0
    moe_experts: int = 0
    moe_top_k: int = 2
    moe_group_size: int = 1024
    moe_capacity_factor: float = 1.25
    d_ff_dense: int = 0               # arctic's parallel dense-residual MLP
    # gdn (paper layer)
    gdn_k_heads: int = 0
    gdn_v_heads: int = 0
    gdn_head_dim: int = 0
    # ssm (mamba2)
    ssm_d_inner: int = 0
    ssm_headdim: int = 0
    ssm_d_state: int = 0
    # rglru (recurrentgemma)
    rglru_width: int = 0
    # misc
    tie_embeddings: bool = False
    frontend_stub: Optional[str] = None   # vision | audio (embeds stand-ins)
    subquadratic: bool = False            # long_500k decode applicable
    norm_eps: float = 1e-6
    act_dtype: str = "bfloat16"
    state_dtype: str = "float32"      # recurrent-state dtype (paper: fp32);
                                      # "bfloat16" = beyond-paper traffic cut
    use_flash_kernel: bool = False    # Pallas flash attention for train
    use_pallas_serving: bool = False  # Pallas fused kernels in prefill/decode
    remat: bool = True
    notes: str = ""

    # ------------------------------------------------------------------
    @property
    def hq_eff(self) -> int:
        return self.n_heads_pad or self.n_heads

    @property
    def hkv_eff(self) -> int:
        return self.n_kv_heads_pad or self.n_kv_heads

    def head_mask(self):
        """(hq_eff,) float mask — 1 for real heads, 0 for TP padding.
        Padding is interleaved per GQA group so the q->kv mapping of real
        heads is unchanged."""
        import numpy as np
        hq, hkv = self.hq_eff, self.hkv_eff
        g_pad = hq // hkv
        g_real = (self.n_heads // self.n_kv_heads
                  if self.n_kv_heads else g_pad)
        h = np.arange(hq)
        real = ((h % g_pad) < g_real) & ((h // g_pad) < self.n_kv_heads)
        return real.astype(np.float32)

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    @property
    def uses_attention(self) -> bool:
        """Any softmax-attention mixer in the pattern (per the registry's
        declarative `is_attention` flag — new kinds classify themselves)."""
        from repro.models.mixers import get_mixer
        return any(get_mixer(k).is_attention for k in self.layer_kinds)

    @property
    def pure_full_attention(self) -> bool:
        """True when every mixer has O(n) decode state (unwindowed softmax
        attention) — no fixed-size persistent state anywhere."""
        from repro.models.mixers import get_mixer
        return all(get_mixer(k).quadratic for k in self.layer_kinds)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Small same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=len(self.pattern),
            d_model=64,
            vocab=256,
            act_dtype="float32",
            remat=False,
            n_heads_pad=0,
            n_kv_heads_pad=0,
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2)),
                      head_dim=16)
            if self.n_kv_heads == self.n_heads:   # preserve MHA structure
                kw["n_kv_heads"] = 4
        if self.window:
            kw["window"] = 32
        if self.d_ff:
            kw["d_ff"] = 128
        if self.d_ff_dense:
            kw["d_ff_dense"] = 128
        if self.moe_experts:
            kw.update(moe_experts=4, moe_group_size=64)
        if self.gdn_v_heads:
            kw.update(gdn_k_heads=2, gdn_v_heads=4, gdn_head_dim=16)
        if self.ssm_d_inner:
            kw.update(ssm_d_inner=128, ssm_headdim=16, ssm_d_state=32)
        if self.rglru_width:
            kw.update(rglru_width=64)
        return self.replace(**kw)


@dataclass(frozen=True)
class ServingTopology:
    """Mesh topology of one serving engine (the jax_pallas analogue of the
    paper's head-parallelism scale-out: slot-axis data parallelism plus
    head/context tensor parallelism under one SPMD tick program).

    ``data``  shards the engine's slot axis (slot counts must be a
    multiple — ``pad_slots`` rounds up so ``fit_spec`` keeps the
    annotation instead of silently dropping it);
    ``model`` shards GDN/SSM state heads and the attention KV context dim
    (the paper's 2→16 value-head design axis, scaled out over devices);
    ``staging_depth`` is the executor's staging-buffer ring size — how
    many ahead-of-slot prefills can be outstanding under saturation.
    """
    data: int = 1
    model: int = 1
    staging_depth: int = 2

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.data, self.model)

    @property
    def axes(self) -> Tuple[str, str]:
        return ("data", "model")

    @property
    def devices(self) -> int:
        return self.data * self.model

    def pad_slots(self, slots: int) -> int:
        """Round a slot count up to a multiple of the data-axis size so the
        slot axis shards evenly (a non-dividing count would make
        ``fit_spec`` drop the DP annotation and replicate every slot)."""
        return -(-slots // self.data) * self.data

    @classmethod
    def parse(cls, text: str, *, staging_depth: int = 2
              ) -> "ServingTopology":
        """Parse a ``--mesh`` flag: "4,2" or "data=4,model=2"."""
        parts = [p.strip() for p in text.split(",") if p.strip()]
        try:
            if any("=" in p for p in parts):
                kv = dict(p.split("=", 1) for p in parts)
                data, model = int(kv.pop("data", 1)), int(kv.pop("model", 1))
                if kv:
                    raise ValueError(f"unknown mesh axes {sorted(kv)}")
            else:
                if len(parts) != 2:
                    raise ValueError("expected two axis sizes")
                data, model = int(parts[0]), int(parts[1])
        except ValueError as e:
            raise ValueError(
                f"--mesh must be 'DATA,MODEL' or 'data=D,model=M', got "
                f"{text!r} ({e})") from None
        if data < 1 or model < 1:
            raise ValueError(f"mesh axis sizes must be >= 1, got "
                             f"data={data}, model={model}")
        return cls(data=data, model=model, staging_depth=staging_depth)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: str) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason when skipped."""
    if shape == "long_500k" and not cfg.subquadratic:
        return False, ("pure full-attention arch: O(n) KV at 500k ctx is "
                       "quadratic-cost/unbounded-memory; skipped per "
                       "assignment (see DESIGN.md)")
    return True, ""
