"""minitron-8b — pruned nemotron dense GQA.

[arXiv:2407.14679; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000, head_dim=128.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    vocab=256000,
    d_model=4096,
    n_layers=32,
    pattern=("attn",),
    ffn="dense",
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    subquadratic=False,
    notes="256k vocab exercises vocab-parallel embedding/logits sharding. "
          "long_500k skipped (full attention).",
)
