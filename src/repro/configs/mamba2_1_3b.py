"""mamba2-1.3b — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=2048 d_ff=0 vocab=50280,
ssm_state=128.  d_inner = 2*d_model = 4096, headdim=64 -> 64 heads.

The paper's technique applies *directly*: SSD decode is the GDN recurrence
without the delta rule (S <- g S + B x^T, y = S^T C), served by the same
fused persistent-state kernel with delta_rule=False.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    vocab=50280,
    d_model=2048,
    n_layers=48,
    pattern=("ssm",),
    ffn="none",
    ssm_d_inner=4096,
    ssm_headdim=64,
    ssm_d_state=128,
    subquadratic=True,
    notes="O(1) state: long_500k decode state = 64 heads x 128 x 64 fp32 "
          "= 2 MB/layer — the paper's persistent-state regime exactly.",
)
