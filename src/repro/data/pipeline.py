"""Data pipeline: deterministic synthetic corpus, document packing, sharded
host feed with background prefetch.

The corpus is a seeded synthetic token stream (documents with Zipf-ish
lengths and a Markov-ish token process) — fully deterministic in
(seed, step, host shard), so a restarted/elastic job resumes bit-identically
from the checkpointed step without any data-state checkpointing.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    eos_id: int = 0
    mean_doc_len: int = 512


def _doc_stream(rng: np.random.Generator, vocab: int, mean_len: int
                ) -> Iterator[np.ndarray]:
    """Endless stream of synthetic 'documents'."""
    while True:
        n = int(np.clip(rng.zipf(1.6) * (mean_len // 8), 8, 8 * mean_len))
        # cheap Markov-ish structure: tokens correlate with their prefix
        base = rng.integers(1, vocab, size=n)
        drift = rng.integers(0, 7, size=n)
        doc = (base + np.cumsum(drift)) % (vocab - 1) + 1  # avoid eos id 0
        yield doc.astype(np.int32)


def pack_documents(docs: Iterator[np.ndarray], seq_len: int, eos_id: int
                   ) -> Iterator[np.ndarray]:
    """Greedy packing of docs into fixed-length rows with EOS separators."""
    buf: list[int] = []
    for doc in docs:
        buf.extend(doc.tolist())
        buf.append(eos_id)
        while len(buf) >= seq_len + 1:
            yield np.asarray(buf[: seq_len + 1], np.int32)
            buf = buf[seq_len + 1:]


class HostDataLoader:
    """Per-host shard of the global batch, deterministic in step index.

    Each host draws from an independent substream keyed by
    (seed, host_index); `batch_at(step)` is reproducible — a restarted job
    re-reads the same data for the same step.
    """

    def __init__(self, cfg: DataConfig, host_index: int = 0,
                 host_count: int = 1, prefetch: int = 2):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // host_count
        self.host_index = host_index
        self._row_cache: dict[int, np.ndarray] = {}
        self._q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._cursor = 0
        self._thread: Optional[threading.Thread] = None

    def _rows_for_step(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.cfg.seed, self.host_index, step))
        packer = pack_documents(
            _doc_stream(rng, self.cfg.vocab, self.cfg.mean_doc_len),
            self.cfg.seq_len, self.cfg.eos_id)
        return np.stack([next(packer) for _ in range(self.local_batch)])

    def batch_at(self, step: int) -> dict:
        rows = self._rows_for_step(step)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    # ---- background prefetch ------------------------------------------
    def start(self, start_step: int = 0):
        self._cursor = start_step

        def worker():
            s = start_step
            while True:
                self._q.put((s, self.batch_at(s)))
                s += 1

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()

    def next(self) -> tuple[int, dict]:
        if self._thread is None:
            b = self.batch_at(self._cursor)
            self._cursor += 1
            return self._cursor - 1, b
        return self._q.get()
