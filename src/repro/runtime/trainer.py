"""Fault-tolerant distributed training runtime.

Production behaviours implemented (and unit-tested on CPU):
  * jitted train_step with NamedSharding in/out + donated state (params and
    optimizer moments update in place — no per-step copies)
  * checkpoint/restart: atomic async checkpoints every `ckpt_every`;
    `run()` auto-resumes from the latest complete checkpoint, and any
    exception inside the step loop triggers restore-and-continue with
    bounded retries (node-failure recovery path)
  * elastic re-mesh: on (re)start the data mesh is rebuilt from the devices
    actually present; checkpoints are loaded with the *new* sharding, so a
    job restarted with a different pod slice resumes seamlessly
  * straggler detection: per-step wall-time EWMA + deviation; slow steps
    are logged with a z-score (the hook a real cluster uses to trigger
    hot-spare swaps)
  * deterministic data: the loader is keyed by (seed, host, step) — resume
    replays the exact batch stream
  * microbatch gradient accumulation (remat-ed scan) for global batches
    larger than device memory allows.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import manager as ckpt
from repro.configs.base import ArchConfig
from repro.data.pipeline import DataConfig, HostDataLoader
from repro.models import lm
from repro.optim import optimizers as opt
from repro.parallel import sharding

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1
    peak_lr: float = 3e-4
    warmup_steps: int = 10
    schedule: str = "cosine"            # cosine | wsd
    adamw: opt.AdamWConfig = field(default_factory=opt.AdamWConfig)
    accum_dtype: str = "float32"        # bf16 for the ~0.5T archs
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    log_every: int = 10
    seed: int = 0
    max_restarts: int = 3
    straggler_ewma: float = 0.9
    straggler_zscore: float = 3.0


def make_data_mesh() -> Mesh:
    """Elastic 1-D data mesh over whatever devices are currently present."""
    devs = np.array(jax.devices())
    return Mesh(devs.reshape(len(devs), 1), ("data", "model"))


def make_schedule(tc: TrainerConfig) -> Callable:
    if tc.schedule == "wsd":
        stable = max(1, int(0.8 * tc.steps) - tc.warmup_steps)
        decay = max(1, tc.steps - tc.warmup_steps - stable)
        return opt.wsd_schedule(tc.peak_lr, tc.warmup_steps, stable, decay)
    return opt.cosine_schedule(tc.peak_lr, tc.warmup_steps, tc.steps)


def init_state(key, cfg: ArchConfig, tc: TrainerConfig):
    params = lm.init_lm(key, cfg)
    return {"params": params,
            "opt": opt.init_adamw(params, tc.adamw),
            "step": jnp.zeros((), jnp.int32)}


def build_train_step(cfg: ArchConfig, tc: TrainerConfig,
                     dp_axes: tuple = ("data",)):
    schedule = make_schedule(tc)
    dp = dp_axes if tc.global_batch % tc.microbatches == 0 else None

    def loss(params, batch):
        return lm.loss_fn(params, cfg, batch, dp_axes=dp)

    def train_step(state, batch):
        if tc.microbatches > 1:
            def resplit(x):
                x = x.reshape((tc.microbatches,
                               x.shape[0] // tc.microbatches) + x.shape[1:])
                # keep the *sequence* batch dim sharded on DP — without this
                # GSPMD moves the sharding to the microbatch (scan) axis and
                # every device materializes the full microbatch
                spec = P(None, dp_axes, *([None] * (x.ndim - 2)))
                return jax.lax.with_sharding_constraint(x, spec)
            mb = jax.tree.map(resplit, batch)

            adt = jnp.dtype(tc.accum_dtype)

            def acc(carry, b):
                (l, m), g = jax.value_and_grad(loss, has_aux=True)(
                    state["params"], b)
                carry = jax.tree.map(
                    lambda c, u: (c + u.astype(c.dtype)), carry, (l, g))
                return carry, m

            zero = (jnp.float32(0.0),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, adt),
                                 state["params"]))
            (lsum, gsum), ms = jax.lax.scan(acc, zero, mb)
            l = lsum / tc.microbatches
            grads = jax.tree.map(lambda g: g / tc.microbatches, gsum)
            metrics = jax.tree.map(lambda x: jnp.mean(x), ms)
        else:
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
                state["params"], batch)
        lr = schedule(state["step"])
        new_params, new_opt, gnorm = opt.adamw_update(
            grads, state["opt"], state["params"], lr, tc.adamw)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        metrics = dict(metrics, loss=l, lr=lr, grad_norm=gnorm)
        return new_state, metrics

    return train_step


class Trainer:
    def __init__(self, cfg: ArchConfig, tc: TrainerConfig,
                 mesh: Optional[Mesh] = None):
        self.cfg, self.tc = cfg, tc
        self.mesh = mesh or make_data_mesh()
        self.loader = HostDataLoader(DataConfig(
            vocab=cfg.vocab, seq_len=tc.seq_len,
            global_batch=tc.global_batch, seed=tc.seed))
        self.ckpt = (ckpt.CheckpointManager(tc.ckpt_dir)
                     if tc.ckpt_dir else None)
        self._compiled = None
        self.state = None
        self.step_times: list[float] = []
        self._ewma = None
        self._ewvar = 0.0
        self.restarts = 0

    # ------------------------------------------------------------------
    def _shardings(self, state):
        fsdp = sharding.needs_fsdp(self.cfg, self.mesh)
        pspecs = sharding.params_specs(
            self.cfg, jax.eval_shape(lambda s: s["params"], state), fsdp,
            self.mesh)
        state_specs = {"params": pspecs,
                       "opt": {"mu": opt_moment_specs(
                           jax.eval_shape(lambda s: s["opt"]["mu"], state),
                           pspecs),
                           "count": P()},
                       "step": P()}
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s),
                            state_specs,
                            is_leaf=lambda x: isinstance(x, P))

    def _batch_sharding(self, batch):
        specs = sharding.batch_specs(self.mesh, batch)
        return {k: NamedSharding(self.mesh, s) for k, s in specs.items()}

    def compile(self):
        key = jax.random.PRNGKey(self.tc.seed)
        with jax.default_device(jax.devices()[0]):
            state = init_state(key, self.cfg, self.tc)
        st_sh = self._shardings(state)
        self.state = jax.device_put(state, st_sh)
        step_fn = build_train_step(self.cfg, self.tc)
        _, b0 = self.loader.next()
        self.loader._cursor = 0
        b_sh = self._batch_sharding(b0)
        self._compiled = jax.jit(step_fn, in_shardings=(st_sh, b_sh),
                                 out_shardings=(st_sh, None),
                                 donate_argnums=(0,))
        self._batch_shardings = b_sh
        return self

    # ------------------------------------------------------------------
    def _record_step_time(self, dt: float, step: int):
        self.step_times.append(dt)
        if self._ewma is None:
            self._ewma = dt
            return
        a = self.tc.straggler_ewma
        dev = dt - self._ewma
        self._ewvar = a * self._ewvar + (1 - a) * dev * dev
        self._ewma = a * self._ewma + (1 - a) * dt
        z = dev / max(np.sqrt(self._ewvar), 1e-9)
        if z > self.tc.straggler_zscore and len(self.step_times) > 5:
            log.warning("straggler suspected at step %d: %.3fs (z=%.1f, "
                        "ewma %.3fs) — flagged for hot-spare rotation",
                        step, dt, z, self._ewma)

    def _maybe_restore(self):
        if self.ckpt is None:
            return 0
        restored, step = self.ckpt.restore_latest(
            jax.tree.map(np.asarray, self.state))
        if restored is None:
            return 0
        sh = self._shardings(restored)
        self.state = jax.device_put(restored, sh)
        log.info("restored checkpoint at step %s (mesh %s)", step,
                 dict(self.mesh.shape))
        return int(step)

    def run(self, fail_at: Optional[int] = None):
        """Train to tc.steps with restore-on-failure. `fail_at` injects a
        fault once (for tests / chaos drills)."""
        if self._compiled is None:
            self.compile()
        start = self._maybe_restore()
        step = start
        injected = False
        history = []
        while step < self.tc.steps:
            try:
                _, batch = self.loader._cursor, self.loader.batch_at(step)
                batch = jax.device_put(batch, self._batch_shardings)
                if fail_at is not None and step == fail_at and not injected:
                    injected = True
                    raise RuntimeError("injected node failure")
                t0 = time.perf_counter()
                with self.mesh:
                    self.state, metrics = self._compiled(self.state, batch)
                metrics["loss"].block_until_ready()
                self._record_step_time(time.perf_counter() - t0, step)
                step += 1
                if step % self.tc.log_every == 0 or step == self.tc.steps:
                    history.append((step, float(metrics["loss"])))
                    log.info("step %d loss %.4f lr %.2e", step,
                             float(metrics["loss"]),
                             float(metrics["lr"]))
                if self.ckpt and step % self.tc.ckpt_every == 0:
                    self.ckpt.save(self.state, step,
                                   blocking=not self.tc.ckpt_async)
            except Exception as e:  # noqa: BLE001 — node-failure recovery
                self.restarts += 1
                if self.restarts > self.tc.max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring from latest "
                            "checkpoint (restart %d/%d)", step, e,
                            self.restarts, self.tc.max_restarts)
                restored = self._maybe_restore()
                step = restored
        if self.ckpt:
            self.ckpt.save(self.state, step, blocking=True)
        return history


def pspecs_for_opt(p: P) -> P:
    return p


def opt_moment_specs(mu_shape, pspecs):
    """Moments follow their parameter's spec; factored moments drop the
    reduced axis; error-feedback buffers follow the parameter."""
    def per_param(spec, st):
        out = {}
        for k, v in st.items():
            if k in ("m", "v", "ef"):
                out[k] = spec
            else:                        # v_row / v_col: one axis reduced
                out[k] = P(*list(spec)[: len(v.shape)])
        return out

    return jax.tree.map(per_param, pspecs, mu_shape,
                        is_leaf=lambda x: isinstance(x, P))
