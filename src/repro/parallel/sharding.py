"""Sharding rules: params / batches / decode caches -> PartitionSpecs.

Axes:
  * batch (DP)        -> ("pod", "data") when the pod axis exists
  * tensor (TP/EP)    -> "model"   (attention & GDN heads, FFN hidden,
                                    MoE experts, vocab)
  * FSDP/ZeRO         -> "data" additionally shards the non-model dim of
                          every large matrix + optimizer moments (enabled
                          automatically for archs whose per-device footprint
                          would exceed HBM; see `needs_fsdp`)
  * SP                -> long-context prefill shards the sequence dim on
                          "data" (activations only; handled by GSPMD from
                          the batch spec when batch < data axis)

Decode caches: batch on DP when it covers the axis; otherwise the *context*
dim is sharded on "model" (flash-decode split-K: each device scans 1/16 of
the KV cache) and linear-state archs shard heads on "model" (the paper's
head parallelism, scaled out).
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


# ---------------------------------------------------------------- helpers

def mesh_axis(mesh: Mesh, name: str) -> bool:
    return name in mesh.axis_names


def dp_axes(mesh: Mesh):
    return ("pod", "data") if mesh_axis(mesh, "pod") else ("data",)


def axis_size(mesh: Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def path_str(path) -> str:
    return "/".join(
        str(getattr(p, "key", None) or getattr(p, "name", None)
            or getattr(p, "idx", p)) for p in path)


def fit_spec(spec: P, shape, mesh: Mesh) -> P:
    """Make a spec valid for jit in/out shardings: every annotated dim must
    divide evenly.  Non-dividing axes are dropped; a dropped 'model' (TP)
    axis is re-placed on the last free dim it divides (e.g. head_dim when
    the head count is odd, vocab -> d_model for prime vocabs), so tensor
    parallelism is preserved wherever the shapes allow."""
    axes = list(spec) + [None] * (len(shape) - len(spec))
    dropped = []
    for i, ax in enumerate(axes):
        if ax is None:
            continue
        if shape[i] % axis_size(mesh, ax) != 0:
            dropped.append(ax)
            axes[i] = None
    for ax in dropped:
        for i in range(len(shape) - 1, -1, -1):
            if axes[i] is None and shape[i] % axis_size(mesh, ax) == 0 \
                    and shape[i] > 1:
                axes[i] = ax
                break
    return P(*axes)


# ---------------------------------------------------------------- params

def param_spec(path: str, shape, fsdp: bool) -> P:
    """Partition spec for one parameter leaf, by key-path pattern."""
    F = "data" if fsdp else None
    M = "model"

    def pick(*axes):
        # drop annotations that don't divide cleanly enough to be useful
        return P(*axes)

    # --- embeddings / head
    if path.endswith("embed/table"):
        return pick(M, F)                        # vocab-parallel
    if path.endswith("lm_head/w"):
        return pick(F, M)

    # --- norms, scalars, gates
    if re.search(r"(norm\d?|final_norm)/scale", path) or path.endswith("/b"):
        return P(None)
    if re.search(r"(A_log|dt_bias|Lambda|/D)$", path):
        return pick(M)

    # --- MoE (expert-parallel on model)
    if "/moe/" in path:
        if path.endswith("router"):
            return P(None, None)
        if path.endswith(("wi_gate", "wi_up")):
            return pick(M, F, None)              # (E, D, F)
        if path.endswith("wo"):
            return pick(M, None, F)              # (E, F, D)

    # --- dense MLP
    if "/mlp/" in path:
        if path.endswith(("wi_gate", "wi_up")):
            return pick(F, M)
        if path.endswith("wo"):
            return pick(M, F)

    # --- attention / GDN mixers
    if "/mixer/" in path:
        if path.endswith(("wq", "wk", "wv")):
            return pick(F, M, None)              # (D, H, hd): heads on TP
        if path.endswith("wo"):
            return pick(M, None, F)              # (H, hd, D)
        if path.endswith(("w_alpha", "w_beta")):
            return pick(F, M)
        # ssm projections
        if path.endswith(("w_z", "w_x")):
            return pick(F, M)                    # d_inner on TP
        if path.endswith(("w_B", "w_C")):
            return pick(F, None)                 # head-shared: replicated
        if path.endswith("w_dt"):
            return pick(F, M)
        if re.search(r"conv_x/w$", path):
            return P(None, M)
        if re.search(r"conv_[BC]/w$", path):
            return P(None, None)
        # rglru — gate matmuls are column-parallel (output W sharded, full
        # input gathered once): row-parallel here made every gate a psum of
        # the full (B, T, W) activation (EXPERIMENTS.md §Perf i5)
        if path.endswith(("in_x", "in_y")):
            return pick(F, M)
        if path.endswith(("w_a", "w_x")):
            return pick(None, M)
        if re.search(r"conv/w$", path):
            return P(None, M)
        if path.endswith("out"):
            return pick(M, F)
        if path.endswith("out_proj"):
            return pick(M, F)
    if path.endswith("out_proj"):
        return pick(M, F)

    return P()                                   # replicate by default


def _prepend_stack_dim(spec: P) -> P:
    """Layer-stacked params get a leading (repeats,) dim: unsharded."""
    return P(None, *spec)


def params_specs(cfg: ArchConfig, params_shape, fsdp: bool, mesh: Mesh):
    """Pytree of PartitionSpec matching a params (shape-)pytree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        ps = path_str(path)
        spec = param_spec(ps, leaf.shape, fsdp)
        if ps.startswith("groups/"):
            spec = _prepend_stack_dim(spec)
        # sanity: never annotate more axes than the leaf has dims
        if len(spec) > len(leaf.shape):
            spec = P(*list(spec)[: len(leaf.shape)])
        specs.append(fit_spec(spec, leaf.shape, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def needs_fsdp(cfg: ArchConfig, mesh: Mesh, hbm_budget_gb: float = 10.0
               ) -> bool:
    """Shard params/moments over data too when TP alone won't fit HBM.

    Rough estimate: bytes/param = 2 (bf16 param) + 2 (bf16 grad)
    + 10 (adam m+v fp32 ... conservatively fp32) sharded model-axis only.
    """
    n_params = estimate_params(cfg)
    per_dev = n_params * (2 + 2 + 10) / axis_size(mesh, "model")
    return per_dev > hbm_budget_gb * 1e9


def estimate_params(cfg: ArchConfig) -> int:
    from repro.models.mixers import get_mixer
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    total = V * d * (1 if cfg.tie_embeddings else 2)
    kinds = cfg.layer_kinds
    for kind in kinds:
        # per-mixer parameter counts are declared by the registry
        total += get_mixer(kind).param_count(cfg)
        if cfg.ffn in ("dense",):
            total += 3 * d * cfg.d_ff
        if cfg.ffn in ("moe", "moe+dense"):
            total += 3 * d * cfg.d_ff * cfg.moe_experts + d * cfg.moe_experts
        if cfg.ffn == "moe+dense":
            total += 3 * d * (cfg.d_ff_dense or cfg.d_ff)
    return int(total)


# ---------------------------------------------------------------- batches

def batch_specs(mesh: Mesh, batch_shape: dict) -> dict:
    dp = dp_axes(mesh)
    specs = {}
    for k, v in batch_shape.items():
        nd = len(v.shape)
        specs[k] = fit_spec(P(dp, *([None] * (nd - 1))), v.shape, mesh)
    return specs


# ---------------------------------------------------------------- caches

def cache_specs(cfg: ArchConfig, mesh: Mesh, caches_shape, batch: int):
    """Decode/prefill cache shardings (see module docstring)."""
    dp = dp_axes(mesh)
    dp_ok = batch % axis_size(mesh, dp) == 0
    BD = dp if dp_ok else None

    def leaf_spec(path, leaf):
        ps = path_str(path)
        shape = leaf.shape           # leading dim = layer-stack repeats
        nd = len(shape)
        if ps.endswith("/k") or ps.endswith("/v"):
            # KVCache (R, B, Hkv, S, hd): shard context dim on model
            return P(None, BD, None, "model", None)
        if ps.endswith("length"):
            return P(None, BD)
        if ps.endswith("/S"):
            # linear state (R, B, Hv, dk, dv): heads on model (paper's
            # head-parallelism); dk additionally on data at tiny batch
            if dp_ok:
                return P(None, BD, "model", None, None)
            return P(None, None, "model", "data", None)
        if ps.endswith("/h"):
            return P(None, BD, "model")
        if "conv" in ps:
            return P(None, BD, None, "model") if nd == 4 else \
                P(*([None] * nd))
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_shape)
    specs = [fit_spec(leaf_spec(p, l), l.shape, mesh) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------- serving

def slot_specs(cfg: ArchConfig, mesh: Mesh, caches_shape, max_slots: int):
    """Serving slot-buffer shardings: the engine's cache pytree with the
    *slot* axis (dim 1, after the layer-stack repeats) on "data" and the
    paper's head parallelism scaled out on "model" — GDN/SSM state heads
    and the attention KV context dim, exactly the decode-cache rules
    above (``cache_specs`` with batch = slots)."""
    return cache_specs(cfg, mesh, caches_shape, max_slots)


def checkpoint_specs(cfg: ArchConfig, mesh: Mesh, ckpt_shape,
                     max_slots: int):
    """Speculative-decode checkpoint-buffer shardings: the rollback image
    is leaf-for-leaf a slot-cache copy (``lm.checkpoint_specs`` defaults
    every mixer's checkpoint to its full cache spec), so it shards under
    exactly the slot rules — slot axis on "data", state heads / KV
    context on "model".  Keeping the placements identical is what lets
    the verify program's conditional commit (select between run-ahead and
    committed trees) and the caches↔checkpoint buffer ping-pong stay
    communication-free: both trees of every pair live on the same
    devices, same layout."""
    return cache_specs(cfg, mesh, ckpt_shape, max_slots)


def staging_specs(slot_spec_tree):
    """Staging-buffer shardings derived from the slot specs: the staging
    pytree is the same cache layout at slot-count 1, so the slot ("data")
    annotation is cleared while every other axis (state heads / KV context
    on "model") keeps the *same* placement — the slot scatter then moves
    data only along the slot axis, never resharding heads."""
    def drop_slot(spec: P) -> P:
        axes = list(spec)
        if len(axes) > 1:
            axes[1] = None
        return P(*axes)
    return jax.tree.map(drop_slot, slot_spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def sampler_specs(mesh: Mesh, sampler_shape, max_slots: int):
    """Per-slot sampler arrays ((S,) / (S, 2) leaves): slot axis on the DP
    axes when it divides, replicated otherwise (never re-placed — a PRNG
    key's lane dim must not be split across devices)."""
    dp = dp_axes(mesh)
    dp_ok = max_slots % axis_size(mesh, dp) == 0
    return jax.tree.map(
        lambda v: P(dp if dp_ok else None,
                    *([None] * (len(v.shape) - 1))), sampler_shape)


def token_slot_spec(mesh: Mesh, max_slots: int) -> P:
    """The (S,) last-token vector: slot axis on DP when it divides."""
    dp = dp_axes(mesh)
    return P(dp) if max_slots % axis_size(mesh, dp) == 0 else P(None)


# ---------------------------------------------------------------- apply

def make_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def replicated(mesh: Mesh, tree):
    """Fully-replicated NamedSharding pytree matching ``tree``'s leaves."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
