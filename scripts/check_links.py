#!/usr/bin/env python3
"""Fail CI on broken intra-repo markdown links.

Scans README.md and docs/**/*.md for ``[text](target)`` links, resolves
relative targets against the file that contains them, and exits non-zero
listing every target that does not exist.  External links (scheme://),
mailto: and pure-fragment (#anchor) links are ignored; fenced code blocks
are stripped before scanning so code samples can't false-positive.

    python scripts/check_links.py [files...]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+?)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^```.*?^```", re.MULTILINE | re.DOTALL)


def _targets(text: str):
    for m in LINK_RE.finditer(FENCE_RE.sub("", text)):
        t = m.group(1)
        if "://" in t or t.startswith(("mailto:", "#")):
            continue
        yield t.split("#", 1)[0]


def check(paths) -> int:
    broken = []
    for md in paths:
        if not md.is_file():
            broken.append(f"{md}: input file does not exist")
            continue
        for target in _targets(md.read_text(encoding="utf-8")):
            if not (md.parent / target).exists():
                broken.append(f"{md}: {target}")
    for b in broken:
        print(f"BROKEN LINK  {b}")
    print(f"checked {len(paths)} files, {len(broken)} broken links")
    return 1 if broken else 0


def main() -> int:
    root = pathlib.Path(__file__).resolve().parent.parent
    if len(sys.argv) > 1:
        paths = [pathlib.Path(p) for p in sys.argv[1:]]
    else:
        # the docs suite is pinned: a deleted/renamed doc fails the job
        # rather than silently shrinking the scan
        pinned = [root / "README.md", root / "docs" / "paper_map.md",
                  root / "docs" / "serving.md"]
        paths = list(dict.fromkeys(
            pinned + sorted((root / "docs").glob("**/*.md"))))
    return check(paths)


if __name__ == "__main__":
    sys.exit(main())
