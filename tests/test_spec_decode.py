"""Speculative decode (draft–verify with recurrent-state rollback):
the engine must never change a token.

The verify program teacher-forces the target's ``decode_step`` over the
draft proposals and samples every position with the SAME per-slot
``(seed, rid)``-folded key sequence non-speculative decode consumes
(``sampling.sample_where`` advances a slot's key only where the slot is
still accepting), so acceptance == "the draft guessed what the target
was going to emit anyway" and the emitted stream is *bitwise* the
non-speculative one — greedy AND stochastic, regardless of what the
draft proposes.  Rejected positions roll the recurrent state back
through the checkpoint buffers declared by
``SequenceMixer.checkpoint_spec``.  Pinned here:

  * speculative (self-draft) greedy streams == non-speculative greedy
    streams for all five mixer kinds + gdn_naive;
  * stochastic parity with a self-draft AND an adversarial draft
    (random re-initialised weights, near-zero acceptance) — the
    shared-key coupling makes the draft quality a pure perf knob;
  * rollback parity at the executor level: a verify tick whose drafts
    are ALL rejected leaves every slot bitwise identical to one plain
    decode step, and a done-at-entry slot bitwise unchanged;
  * pause/preempt during a pending draft defer to the verify boundary
    (the request stays ACTIVE, swaps on the next step) and a resume
    before the boundary cancels the pause — streams stay bitwise;
  * acceptance metrics: self-draft acceptance ≈ 1, host syncs per
    emitted token < 1; checkpoint byte budgets come from the spec;
  * constructor/submit validation and the analytical intensity model's
    speculative profile.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import intensity
from repro.models import lm
from repro.models.mixers import get_mixer
from repro.serving import scheduler as sched
from repro.serving.engine import DecodeEngine, Request

ARCHS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}
KINDS = list(ARCHS) + ["gdn_naive"]

_MODELS = {}


def _model(kind):
    if kind not in _MODELS:
        cfg = configs.get_arch(ARCHS.get(kind, ARCHS["gdn"])).reduced()
        if kind == "gdn_naive":
            cfg = cfg.replace(pattern=tuple(
                "gdn_naive" if k == "gdn" else k for k in cfg.pattern))
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        _MODELS[kind] = (cfg, params)
    return _MODELS[kind]


def _engine(kind, **kw):
    cfg, params = _model(kind)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_block", 2)
    kw.setdefault("prefill_chunk", 8)
    return DecodeEngine(cfg, params, **kw)


def _reqs(n, stochastic, max_new=8):
    return [Request(rid=i, prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                    max_new_tokens=max_new + i,
                    temperature=0.8 if stochastic and i % 2 == 0 else 0.0,
                    top_k=10 if stochastic and i % 2 == 0 else 0,
                    top_p=0.9 if stochastic and i % 2 == 0 else 1.0)
            for i in range(n)]


def _streams(reqs):
    return [list(r.output) for r in reqs]


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return _streams(reqs)


def _step_until(eng, pred, max_ticks=100):
    for _ in range(max_ticks):
        eng.step()
        if pred():
            return
    raise AssertionError("condition not reached")


# ------------------------------------------------- bitwise stream parity

@pytest.mark.parametrize("kind", KINDS)
def test_spec_greedy_bitwise(kind):
    """Self-draft speculative greedy == non-speculative greedy, per
    mixer family.  The verify emits the target's own argmax at every
    position, so the draft can only change how many tokens ride on one
    host sync — never which tokens."""
    ref = _run(_engine(kind), _reqs(3, False))
    spec = _run(_engine(kind, speculative=True, k_draft=4),
                _reqs(3, False))
    assert spec == ref


@pytest.mark.parametrize("draft", ["self", "adversarial"])
def test_spec_stochastic_bitwise(draft):
    """Stochastic parity through the shared key schedule: the verify's
    position-j sample consumes exactly the key non-speculative decode
    would, so streams are bitwise even when the draft is a freshly
    re-initialised model that almost never agrees (acceptance ~ 0, every
    tick exercises the rollback)."""
    cfg, params = _model("gdn")
    kw = {}
    if draft == "adversarial":
        kw = dict(draft_cfg=cfg,
                  draft_params=lm.init_lm(jax.random.PRNGKey(99), cfg))
    ref = _run(_engine("gdn"), _reqs(3, True))
    eng = _engine("gdn", speculative=True, k_draft=4, **kw)
    spec = _run(eng, _reqs(3, True))
    assert spec == ref
    m = eng.metrics()
    if draft == "adversarial":
        assert m["acceptance_rate"] < 0.5     # rollback actually ran
    else:
        assert m["acceptance_rate"] > 0.5


def test_spec_parity_across_draft_lengths():
    """k_draft moves sync cadence only: streams are bitwise identical
    across draft lengths (including k=1, the degenerate two-position
    verify)."""
    ref = _run(_engine("gdn"), _reqs(2, True))
    for k in (1, 2, 8):
        assert _run(_engine("gdn", speculative=True, k_draft=k),
                    _reqs(2, True)) == ref, f"k_draft={k} diverged"


# ------------------------------------------------------ rollback parity

def _primed_spec_engines():
    """Two bitwise-identical speculative engines with both slots active
    (same submissions, same ticks — identical device state)."""
    engs = []
    for _ in range(2):
        eng = _engine("gdn", speculative=True, k_draft=4)
        reqs = _reqs(2, False, max_new=20)
        for r in reqs:
            eng.submit(r)
        _step_until(eng, lambda: len(eng.active) == 2)
        eng.step()                  # one more tick mid-stream
        engs.append(eng)
    return engs


def test_fully_rejected_tick_equals_one_decode_step():
    """A verify tick whose drafts are ALL rejected (proposals of -1 can
    never match a sampled token) must leave every slot — recurrent
    state, rolling window, sampler row, last token — bitwise identical
    to one plain non-speculative decode step: the checkpoint rollback
    restores everything the run-ahead touched."""
    a, b = _primed_spec_engines()
    xa, xb = a.executor, b.executor
    k = 4
    toks_a, valid_a = xa.decode(1)              # the non-spec reference
    bad = xb._put(jnp.full((k, xb.max_slots), -1, jnp.int32),
                  xb._sh_toks2d)
    toks_b, valid_b = xb.spec_verify(k, bad)
    # only the verify's own sample survives; every draft is rejected
    assert valid_b[0].all() and not valid_b[1:].any()
    np.testing.assert_array_equal(toks_b[0], toks_a[0])
    for slot in range(xa.max_slots):
        sa, sb = xa.gather_slot(slot), xb.gather_slot(slot)
        assert sa.sampler.keys() == sb.sampler.keys()
        for kk in sa.sampler:
            np.testing.assert_array_equal(sa.sampler[kk], sb.sampler[kk],
                                          err_msg=f"sampler[{kk}]")
        np.testing.assert_array_equal(sa.token, sb.token)
        la, lb = jax.tree.leaves(sa.caches), jax.tree.leaves(sb.caches)
        assert len(la) == len(lb)
        for i, (x, y) in enumerate(zip(la, lb)):
            np.testing.assert_array_equal(x, y, err_msg=f"cache leaf {i}")


def test_done_slot_is_bitwise_unchanged_by_verify():
    """A slot whose sampler is done at verify entry emits nothing and
    commits nothing: its full residency is bitwise unchanged by the
    tick (non-speculative decode would still have churned its cache —
    the rollback makes 'no tokens' mean 'no state change')."""
    _, b = _primed_spec_engines()
    xb = b.executor
    xb.sampler = {kk: (v.at[1].set(True) if kk == "done" else v)
                  for kk, v in xb.sampler.items()}
    before = xb.gather_slot(1)
    bad = xb._put(jnp.full((4, xb.max_slots), -1, jnp.int32),
                  xb._sh_toks2d)
    toks, valid = xb.spec_verify(4, bad)
    assert not valid[:, 1].any()                # emitted nothing
    after = xb.gather_slot(1)
    np.testing.assert_array_equal(before.token, after.token)
    for kk in before.sampler:
        np.testing.assert_array_equal(before.sampler[kk],
                                      after.sampler[kk],
                                      err_msg=f"sampler[{kk}]")
    for i, (x, y) in enumerate(zip(jax.tree.leaves(before.caches),
                                   jax.tree.leaves(after.caches))):
        np.testing.assert_array_equal(x, y, err_msg=f"cache leaf {i}")


# ------------------------------------------- pause at the verify boundary

def test_pause_during_pending_draft_defers_to_verify():
    """pause() while a draft is in flight keeps the request ACTIVE (its
    residency between draft and verify is not a self-consistent image),
    swaps it at the next verify boundary, and the resumed stream is
    bitwise the never-paused one."""
    ref = _run(_engine("gdn", speculative=True, k_draft=4),
               _reqs(2, True, max_new=10))
    eng = _engine("gdn", speculative=True, k_draft=4)
    reqs = _reqs(2, True, max_new=10)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (len(eng.active) == 2
                              and eng._pending is not None))
    assert eng.pause(0) is reqs[0]
    assert reqs[0].state == sched.ACTIVE        # deferred, not swapped
    assert 0 not in eng.swapped
    eng.step()                                  # verify, then swap out
    assert reqs[0].state in (sched.SWAPPED, sched.DONE)
    if reqs[0].state == sched.SWAPPED:
        eng.step()                              # neighbor keeps decoding
        eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref


def test_resume_before_boundary_cancels_deferred_pause():
    ref = _run(_engine("gdn", speculative=True, k_draft=4),
               _reqs(2, False, max_new=10))
    eng = _engine("gdn", speculative=True, k_draft=4)
    reqs = _reqs(2, False, max_new=10)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (len(eng.active) == 2
                              and eng._pending is not None))
    eng.pause(0)
    assert eng.resume(0) is reqs[0]             # cancel before the verify
    assert reqs[0].state == sched.ACTIVE
    eng.run_until_done()
    assert _streams(reqs) == ref
    assert eng.metrics()["swap_outs"] == 0


def test_preempt_during_pending_draft_defers():
    """preempt() mid-draft defers exactly like pause, but re-queues the
    victim for automatic resume."""
    ref = _run(_engine("gdn", speculative=True, k_draft=4),
               _reqs(2, False, max_new=10))
    eng = _engine("gdn", speculative=True, k_draft=4)
    reqs = _reqs(2, False, max_new=10)
    reqs[0].priority = 1
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (len(eng.active) == 2
                              and eng._pending is not None))
    assert eng.preempt() is reqs[1]             # lowest priority
    assert reqs[1].state == sched.ACTIVE        # deferred
    eng.run_until_done()                        # swaps, auto-resumes
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref
    assert eng.metrics()["swap_outs"] >= 1


def test_swap_image_is_draft_free():
    """The swap image of a speculative engine is byte-identical in
    layout and budget to a non-speculative one's — the draft caches and
    checkpoints are rebuilt at swap-in, never shipped to host."""
    spec = _engine("gdn", speculative=True, k_draft=4)
    base = _engine("gdn")
    assert (spec.executor.swap_bytes_per_slot
            == base.executor.swap_bytes_per_slot)
    req = _reqs(1, False)[0]
    spec.submit(req)
    _step_until(spec, lambda: req.state == sched.ACTIVE)
    prefills0 = spec.draft_prefills
    spec.pause(0)
    spec.step()                                 # boundary swap executes
    assert req.state == sched.SWAPPED
    assert spec.swapped[0].state.nbytes == base.executor.swap_bytes_per_slot
    spec.resume(0)
    spec.run_until_done()
    assert req.done
    assert spec.draft_prefills > prefills0      # rebuilt at swap-in


# -------------------------------------------------- metrics and budgets

def test_self_draft_acceptance_and_sync_amortisation():
    eng = _engine("gdn", speculative=True, k_draft=4)
    _run(eng, _reqs(3, False, max_new=12))
    m = eng.metrics()
    assert m["speculative"] == 1 and m["k_draft"] == 4
    assert m["spec_ticks"] == m["ticks"] > 0
    assert m["accepted_tokens"] <= m["drafted_tokens"]
    assert m["acceptance_rate"] > 0.6           # self-draft, same chunks
    assert m["syncs_per_token"] < 1.0 / 2       # > 2 tokens per sync
    assert m["draft_prefills"] == 3             # one per admit
    assert (m["checkpoint_bytes_per_slot"] > 0
            and m["draft_bytes_per_slot"] > 0
            and m["speculative_bytes"] > 0)
    progs = eng.executor.compiled_programs()
    assert progs["speculative"] >= 3            # draft + verify + rebuild


def test_nonspec_engine_reports_zero_spec_metrics():
    eng = _engine("gdn")
    _run(eng, _reqs(1, False, max_new=4))
    m = eng.metrics()
    assert m["speculative"] == 0 and m["k_draft"] == 0
    assert m["spec_ticks"] == m["drafted_tokens"] == 0
    assert m["speculative_bytes"] == 0
    assert eng.executor.compiled_programs()["speculative"] == 0


def test_checkpoint_spec_and_intensity_model():
    """checkpoint_spec defaults to the full cache_spec (decode mutates
    every leaf destructively), the byte helpers sum it over layers, and
    the speculative profile amortises host-side cost by emitted tokens
    while keeping state traffic per token honest."""
    cfg, _ = _model("gdn")
    for kind in dict.fromkeys(cfg.layer_kinds):
        ck = get_mixer(kind).checkpoint_spec(cfg, 1, 64)
        cs = get_mixer(kind).cache_spec(cfg, 1, 64)
        assert ck.nbytes == cs.nbytes
        assert intensity.mixer_checkpoint_bytes(cfg, kind, max_len=64) \
            == cs.nbytes
    assert intensity.arch_checkpoint_bytes(cfg, max_len=64) == sum(
        intensity.mixer_checkpoint_bytes(cfg, k, max_len=64)
        for k in cfg.layer_kinds)
    p0 = intensity.speculative_decode_profile(cfg, k_draft=4,
                                              acceptance=0.0)
    p1 = intensity.speculative_decode_profile(cfg, k_draft=4,
                                              acceptance=1.0)
    # same tick work, 5x the emissions: per-token cost falls 5x
    assert p0.flops == pytest.approx(5 * p1.flops)
    assert p1.name.endswith("+spec(k=4)")
    with pytest.raises(ValueError, match="acceptance"):
        intensity.speculative_decode_profile(cfg, k_draft=4,
                                             acceptance=1.5)
    with pytest.raises(ValueError, match="k_draft"):
        intensity.speculative_decode_profile(cfg, k_draft=-1,
                                             acceptance=0.5)


# ----------------------------------------------------------- validation

def test_spec_validation_errors():
    cfg, params = _model("gdn")
    with pytest.raises(ValueError, match="speculative"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     draft_cfg=cfg, draft_params=params)
    with pytest.raises(ValueError, match="k_draft"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     speculative=True, k_draft=0)
    other = configs.get_arch("mamba2-1.3b").reduced()
    if other.vocab != cfg.vocab:
        with pytest.raises(ValueError, match="vocab"):
            DecodeEngine(cfg, params, max_slots=1, max_len=32,
                         speculative=True, draft_cfg=other,
                         draft_params=lm.init_lm(jax.random.PRNGKey(1),
                                                 other))
    eng = _engine("gdn", speculative=True, k_draft=2)
    emb = np.zeros((4, cfg.d_model), np.float32)
    with pytest.raises(ValueError, match="prompt_embeds"):
        eng.submit(Request(rid=0, prompt=None, prompt_embeds=emb,
                           max_new_tokens=2))
