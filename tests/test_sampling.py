"""Device sampler vs NumPy reference: filtering pipeline parity,
greedy/argmax equivalence, done-flag semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import sampling


def _rand_logits(rng, s=5, v=64):
    # continuous values: cutoff ties have measure zero
    return rng.normal(size=(s, v)).astype(np.float32) * 3.0


@pytest.mark.parametrize("temperature,top_k,top_p", [
    (1.0, 0, 1.0),          # plain temperature
    (0.7, 8, 1.0),          # top-k only
    (1.3, 0, 0.9),          # top-p only
    (0.9, 12, 0.8),         # both
    (2.0, 1, 1.0),          # top-k=1 degenerates to argmax support
])
def test_filter_matches_numpy_reference(temperature, top_k, top_p):
    rng = np.random.default_rng(0)
    logits = _rand_logits(rng)
    s = logits.shape[0]
    dev = np.asarray(sampling.filter_logits(
        jnp.asarray(logits),
        jnp.full((s,), temperature, jnp.float32),
        jnp.full((s,), top_k, jnp.int32),
        jnp.full((s,), top_p, jnp.float32)))
    for row in range(s):
        ref = sampling.filter_logits_np(logits[row], temperature, top_k,
                                        top_p)
        # identical support...
        np.testing.assert_array_equal(np.isfinite(dev[row]),
                                      np.isfinite(ref))
        # ...and matching scaled log-probs on it
        keep = np.isfinite(ref)
        np.testing.assert_allclose(dev[row][keep], ref[keep], rtol=1e-4,
                                   atol=1e-4)


def test_top_k_support_size():
    rng = np.random.default_rng(1)
    logits = _rand_logits(rng)
    for k in (1, 4, 16):
        out = np.asarray(sampling.filter_logits(
            jnp.asarray(logits),
            jnp.ones((5,), jnp.float32),
            jnp.full((5,), k, jnp.int32),
            jnp.ones((5,), jnp.float32)))
        assert (np.isfinite(out).sum(-1) == k).all()


def test_top_p_keeps_minimal_prefix():
    rng = np.random.default_rng(2)
    logits = _rand_logits(rng, s=1)[0]
    p = 0.8
    ref = sampling.filter_logits_np(logits, 1.0, 0, p)
    keep = np.isfinite(ref)
    probs = np.exp(logits - np.logaddexp.reduce(logits.astype(np.float64)))
    kept = np.sort(probs[keep])[::-1]
    assert kept.sum() >= p                      # mass reaches the nucleus
    assert kept.sum() - kept[-1] < p            # and is minimal


def _dev_vs_np(logits, temperature, top_k, top_p):
    """Device filter + NumPy reference rows for identical knobs."""
    s = logits.shape[0]
    dev = np.asarray(sampling.filter_logits(
        jnp.asarray(logits),
        jnp.full((s,), temperature, jnp.float32),
        jnp.full((s,), top_k, jnp.int32),
        jnp.full((s,), top_p, jnp.float32)))
    ref = np.stack([sampling.filter_logits_np(row, temperature, top_k,
                                              top_p) for row in logits])
    return dev, ref


def test_top_k_at_least_vocab_is_identity():
    """top_k >= vocab (and the 0 sentinel) must keep the full support —
    the clamp must not drop the last bucket or wrap."""
    rng = np.random.default_rng(6)
    logits = _rand_logits(rng)
    v = logits.shape[-1]
    for k in (0, v, v + 1, 10 * v):
        dev, ref = _dev_vs_np(logits, 1.0, k, 1.0)
        assert np.isfinite(dev).all(), f"top_k={k} dropped entries"
        np.testing.assert_array_equal(np.isfinite(ref), True)
        np.testing.assert_allclose(dev, ref, rtol=1e-5, atol=1e-5)


def test_top_p_one_is_identity():
    """top_p=1.0 is the documented 'disabled' sentinel: the cumulative
    cutoff lands past the last entry and nothing is masked."""
    rng = np.random.default_rng(7)
    logits = _rand_logits(rng)
    dev, ref = _dev_vs_np(logits, 1.0, 0, 1.0)
    assert np.isfinite(dev).all()
    np.testing.assert_allclose(dev, ref, rtol=1e-5, atol=1e-5)


def test_tied_logits_keep_or_drop_consistently():
    """Exactly tied logits at the top-k / top-p cutoff: the device sort
    and the NumPy reference must resolve the tie the same way (stable by
    index), so the supports agree even at measure-zero inputs."""
    v = 16
    base = np.zeros((1, v), np.float32)
    base[0, :8] = 2.0                   # 8-way tie above a 8-way tie
    for top_k, top_p in ((4, 1.0), (8, 1.0), (0, 0.5), (4, 0.6)):
        dev, ref = _dev_vs_np(base, 1.0, top_k, top_p)
        np.testing.assert_array_equal(np.isfinite(dev),
                                      np.isfinite(ref),
                                      err_msg=f"top_k={top_k}, "
                                              f"top_p={top_p}")
        keep = np.isfinite(ref)
        np.testing.assert_allclose(dev[keep], ref[keep], rtol=1e-5,
                                   atol=1e-5)


def test_temperature_to_zero_approaches_argmax():
    """As temperature -> 0 the filtered distribution concentrates on the
    argmax: at tiny but nonzero temperature the scaled logit gap to the
    runner-up must exceed any float32 noise, and sampling must pick the
    argmax."""
    rng = np.random.default_rng(8)
    logits = _rand_logits(rng)
    for t in (1e-2, 1e-3):
        dev, ref = _dev_vs_np(logits, t, 0, 1.0)
        np.testing.assert_allclose(dev, ref, rtol=1e-3, atol=1e-2)
        st = sampling.init_state(5)
        st["done"] = jnp.zeros((5,), bool)
        st["remaining"] = jnp.full((5,), 10, jnp.int32)
        st["temperature"] = jnp.full((5,), t, jnp.float32)
        tok, _ = sampling.sample(st, jnp.asarray(logits))
        np.testing.assert_array_equal(np.asarray(tok), logits.argmax(-1))


def test_temperature_zero_is_argmax():
    rng = np.random.default_rng(3)
    logits = _rand_logits(rng)
    st = sampling.init_state(5)
    st["done"] = jnp.zeros((5,), bool)
    st["remaining"] = jnp.full((5,), 10, jnp.int32)
    tok, _ = sampling.sample(st, jnp.asarray(logits))
    np.testing.assert_array_equal(np.asarray(tok), logits.argmax(-1))
    assert sampling.sample_np(np.random.default_rng(0), logits[0],
                              temperature=0.0) == int(logits[0].argmax())


def test_sampled_tokens_stay_in_filtered_support():
    rng = np.random.default_rng(4)
    logits = _rand_logits(rng)
    st = sampling.init_state(5)
    st["done"] = jnp.zeros((5,), bool)
    st["remaining"] = jnp.full((5,), 100, jnp.int32)
    st["temperature"] = jnp.full((5,), 0.9, jnp.float32)
    st["top_k"] = jnp.full((5,), 5, jnp.int32)
    st["top_p"] = jnp.full((5,), 0.95, jnp.float32)
    support = np.isfinite(np.asarray(sampling.filter_logits(
        jnp.asarray(logits), st["temperature"], st["top_k"], st["top_p"])))
    seen = set()
    for _ in range(20):
        tok, st = sampling.sample(st, jnp.asarray(logits))
        for row, t in enumerate(np.asarray(tok)):
            assert support[row, t]
            seen.add((row, int(t)))
    assert len(seen) > 5        # actually stochastic, not argmax-stuck


def test_done_flags_eos_and_budget():
    v = 16
    logits = np.full((3, v), -5.0, np.float32)
    logits[:, 7] = 5.0                       # greedy token = 7 everywhere
    st = sampling.init_state(3)
    st["done"] = jnp.asarray([False, False, True])
    st["remaining"] = jnp.asarray([5, 1, 5], jnp.int32)
    st["eos_id"] = jnp.asarray([7, -1, -1], jnp.int32)
    tok, st2 = sampling.sample(st, jnp.asarray(logits))
    done = np.asarray(st2["done"])
    assert done[0]                           # hit its EOS
    assert done[1]                           # budget exhausted
    assert done[2]                           # sticky
    # done slot's budget is frozen
    assert int(st2["remaining"][2]) == 5
    # admit re-arms a slot
    st3 = sampling.admit_slot(st2, 2, seed=0, rid=9, temperature=0.0,
                              top_k=0, top_p=1.0, eos_id=None, budget=4)
    assert not bool(st3["done"][2])
    assert int(st3["remaining"][2]) == 4


def test_engine_rejects_out_of_range_params():
    from repro.serving.engine import DecodeEngine, Request
    eng = object.__new__(DecodeEngine)      # submit() needs no jit state
    eng.queue, eng._all = [], []
    with pytest.raises(ValueError, match="top_p"):
        DecodeEngine.submit(eng, Request(rid=0, top_p=0.0))
    with pytest.raises(ValueError, match="top_k"):
        DecodeEngine.submit(eng, Request(rid=0, top_k=-1))
    with pytest.raises(ValueError, match="temperature"):
        # top-k/top-p on a greedy request would silently no-op
        DecodeEngine.submit(eng, Request(rid=0, top_k=40))
    with pytest.raises(ValueError, match="temperature"):
        DecodeEngine.submit(eng, Request(rid=0, top_p=0.9))
    with pytest.raises(ValueError, match="max_new_tokens"):
        DecodeEngine.submit(eng, Request(rid=0, max_new_tokens=0))


def test_per_request_key_is_placement_independent():
    """A request's draw sequence depends on (seed, rid) only — not the
    slot it lands in."""
    rng = np.random.default_rng(5)
    logits = jnp.tile(jnp.asarray(_rand_logits(rng, s=1)), (4, 1))

    def draws(slot):
        st = sampling.init_state(4)
        st = sampling.admit_slot(st, slot, seed=0, rid=42, temperature=1.0,
                                 top_k=0, top_p=1.0, eos_id=None, budget=100)
        out = []
        for _ in range(8):
            tok, st = sampling.sample(st, logits)
            out.append(int(tok[slot]))
        return out

    assert draws(0) == draws(3)
