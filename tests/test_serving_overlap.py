"""Scheduler/executor split: overlapped-staging admit == serialized admit.

The overlap machinery (chunked prefill into the staging buffer, fused
on-device first-token sample, budget-aware tick lengths) must move *when*
work happens, never *what* is computed: every test here pins a pair of
engine configurations to bitwise-identical token streams.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import sampling
from repro.serving.engine import DecodeEngine, Request
from repro.serving.executor import DeviceExecutor
from repro.serving.scheduler import Scheduler


def _arch_cfg(name):
    """Reduced config; REPRO_PALLAS_SERVING=1 (the CI kernel-path job)
    routes prefill/decode through the Pallas kernels (interpret mode on
    CPU) so the masked kernel paths are exercised by the same parity
    suite."""
    cfg = configs.get_arch(name).reduced()
    if os.environ.get("REPRO_PALLAS_SERVING") == "1":
        cfg = cfg.replace(use_pallas_serving=True)
    return cfg


@pytest.fixture(scope="module")
def gdn_model():
    cfg = _arch_cfg("qwen3-next-gdn")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _serve(cfg, params, *, overlap, stochastic=False, decode_block=4,
           budget_ticks=True, prefill_chunk=8, n=6, slots=2):
    eng = DecodeEngine(cfg, params, max_slots=slots, max_len=64,
                       decode_block=decode_block, overlap=overlap,
                       prefill_chunk=prefill_chunk,
                       budget_ticks=budget_ticks)
    reqs = [Request(rid=i,
                    prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                    max_new_tokens=4 + i,
                    temperature=0.8 if stochastic else 0.0,
                    top_k=10 if stochastic else 0,
                    top_p=0.9 if stochastic else 1.0)
            for i in range(n)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.output) for r in reqs]


# ------------------------------------------- chunked prefill == sequential

@pytest.mark.parametrize("arch", ["mamba2-1.3b", "recurrentgemma-2b",
                                  "yi-9b"])
def test_chunked_prefill_matches_serial_decode(arch):
    """Multi-chunk prefill resume across every mixer family: the ssm /
    rglru state carries (conv carries included) and the attention
    rolling-buffer wrap (prompt longer than the KV buffer, max_len 16 <
    T=21) must reproduce token-by-token sequential decode."""
    cfg = _arch_cfg(arch)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    T, max_len = 21, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, T), 1, cfg.vocab)

    serial = lm.init_caches(cfg, 1, max_len)
    logits = None
    for t in range(T):
        logits, serial = lm.decode_step(params, cfg, tokens[:, t], serial)

    chunked = lm.init_caches(cfg, 1, max_len)
    pos = 0
    for s in (8, 8, 4, 1):                # ragged chunks, wrap mid-prompt
        x, chunked = lm.prefill_chunk(params, cfg, chunked,
                                      tokens=tokens[:, pos:pos + s])
        pos += s
    from repro.models import layers
    h = layers.rmsnorm_fwd(params["final_norm"], x[:, -1], cfg.norm_eps)
    np.testing.assert_allclose(np.asarray(lm._logits(params, cfg, h)),
                               np.asarray(logits), rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree.leaves(chunked), jax.tree.leaves(serial)):
        assert a.dtype == b.dtype and a.shape == b.shape
        if a.dtype.kind in "iub":          # cache lengths etc. — exact
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-4, atol=2e-4)


# ----------------------------------------------------- overlap == serial

def test_overlap_parity_greedy(gdn_model):
    """Queued requests streamed through the staging buffer emit exactly
    the tokens the serialized prefill-behind-a-free-slot path emits."""
    cfg, params = gdn_model
    _, ser = _serve(cfg, params, overlap=False)
    _, ovl = _serve(cfg, params, overlap=True)
    assert ovl == ser


def test_overlap_parity_stochastic(gdn_model):
    """Per-request device RNG streams make sampled outputs identical too —
    admit consumes the first split of the (seed, rid) key on device, and
    the scattered row continues the same stream in the slot."""
    cfg, params = gdn_model
    _, ser = _serve(cfg, params, overlap=False, stochastic=True)
    _, ovl = _serve(cfg, params, overlap=True, stochastic=True)
    assert ovl == ser


def test_overlap_parity_across_chunk_sizes(gdn_model):
    """The chunk plan (scan chunks + power-of-two tail) is a pure
    scheduling choice: chunk size never changes the streams."""
    cfg, params = gdn_model
    outs = [_serve(cfg, params, overlap=True, prefill_chunk=c)[1]
            for c in (4, 8, 16)]
    assert outs[0] == outs[1] == outs[2]


def test_overlap_ahead_of_slot_admit(gdn_model):
    """With every slot busy on long budgets, a queued request prefills
    one chunk dispatch per tick (decode proceeds between chunks) and its
    first token is emitted while the slots are still decoding (before any
    slot frees) — the TTFT mechanism the overlap exists for.  Pinned to
    the per-prompt staging path: the batched packer legitimately stages
    the whole prompt in one tick (see tests/test_batched_prefill.py)."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8,
                       prefill_batching=False)
    long = [Request(rid=100 + i, prompt=np.arange(1, 18, dtype=np.int32),
                    max_new_tokens=30) for i in range(2)]
    for r in long:
        eng.submit(r)
    eng.step()                            # both slots now decoding
    queued = Request(rid=0, prompt=np.arange(1, 18, dtype=np.int32),
                     max_new_tokens=4)
    eng.submit(queued)
    # 17-token prompt, chunk 8 -> plan [scan(2), admit(1)]: one chunk
    # dispatch per overlapped tick, token at plan completion
    eng.step()
    assert eng._staging is queued         # mid-plan, decode kept ticking
    assert queued.output == []
    eng.step()
    assert not any(r.done for r in long)  # slots still busy
    assert len(queued.output) == 1        # first token already emitted
    assert queued.t_first is not None     # TTFT stamped at admit confirm
    eng.run_until_done()
    assert queued.done and len(queued.output) == 4


# --------------------------------------------------- fused on-device admit

def test_fused_admit_token_matches_sample_np_greedy(gdn_model):
    """Greedy: the fused on-device first token equals the host mirror
    (``sample_np`` = argmax) over the same chunked-prefill logits —
    replaying the masked plan literally (fixed-size padded tail chunk,
    logits read at the last *valid* position)."""
    cfg, params = gdn_model
    prompt = np.arange(1, 14, dtype=np.int32)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64, overlap=True)
    req = Request(rid=0, prompt=prompt, max_new_tokens=1)
    eng.submit(req)
    eng.run_until_done()
    # host mirror: same chunk plan, logits read out, sample_np draw
    ex = DeviceExecutor(cfg, params, max_slots=1, max_len=64,
                        decode_block=1, prefill_chunk=16)
    caches = lm.init_caches(cfg, 1, 64)
    pos = 0
    C = ex.prefill_chunk
    for step in ex.plan_prefill(len(prompt)):
        chunk = np.asarray(prompt[pos:pos + step.tokens])
        pos += step.tokens
        if step.kind == "scan":
            m = step.size
            pad = np.zeros((m * C - len(chunk),), chunk.dtype)
            caches = lm.prefill_chunk_scan(
                params, cfg, caches,
                tokens=jnp.asarray(np.concatenate([chunk, pad])).reshape(
                    1, m, C),
                valid_lens=jnp.asarray(step.valid, jnp.int32))
        else:
            assert step.kind == "admit"
            pad = np.zeros((step.size - len(chunk),), chunk.dtype)
            x, caches = lm.prefill_chunk(
                params, cfg, caches,
                tokens=jnp.asarray(np.concatenate([chunk, pad]))[None],
                valid_len=jnp.int32(step.valid))
            last = x[:, step.valid - 1]
    from repro.models import layers
    h = layers.rmsnorm_fwd(params["final_norm"], last, cfg.norm_eps)
    logits = np.asarray(lm._logits(params, cfg, h))[0]
    mirror = sampling.sample_np(np.random.default_rng(0), logits,
                                temperature=0.0)
    assert req.output == [mirror]


def test_fused_admit_stochastic_matches_device_mirror(gdn_model):
    """Stochastic: the fused head is ``sampling.sample`` on a 1-row
    ``admit_row`` state — replaying that pipeline on the chunked-prefill
    logits reproduces the engine's first token and its advanced key."""
    cfg, params = gdn_model
    prompt = np.arange(1, 10, dtype=np.int32)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64, overlap=True,
                       seed=3)
    req = Request(rid=7, prompt=prompt, max_new_tokens=1, temperature=0.7,
                  top_k=12)
    eng.submit(req)
    eng.run_until_done()
    caches = lm.init_caches(cfg, 1, 64)
    x, caches = lm.prefill_chunk(params, cfg, caches,
                                 tokens=jnp.asarray(prompt)[None][:, :8])
    x, caches = lm.prefill_chunk(params, cfg, caches,
                                 tokens=jnp.asarray(prompt)[None][:, 8:])
    from repro.models import layers
    h = layers.rmsnorm_fwd(params["final_norm"], x[:, -1], cfg.norm_eps)
    logits = lm._logits(params, cfg, h)
    row = sampling.admit_row(3, 7, 0.7, 12, 1.0, -1, 1)
    tok, row = sampling.sample(row, logits)
    assert req.output == [int(tok[0])]
    assert bool(row["done"][0])           # budget of 1 exhausted on device


# ------------------------------------------------------ budget-aware ticks

def test_budget_ticks_parity(gdn_model):
    """Capping the tick scan length by the max remaining budget (bucketed)
    drops masked tail steps but never changes the streams."""
    cfg, params = gdn_model
    eng_full, full = _serve(cfg, params, overlap=True, budget_ticks=False,
                            decode_block=8)
    eng_budget, budget = _serve(cfg, params, overlap=True,
                                budget_ticks=True, decode_block=8)
    assert budget == full


def test_tick_k_buckets(gdn_model):
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=8, budget_ticks=True)
    for need, want in ((1, 1), (2, 2), (3, 4), (5, 8), (9, 8), (64, 8)):
        eng.active = {0: Request(rid=0, max_new_tokens=need)}
        assert eng._tick_k() == want
    eng.active = {}


# ------------------------------------------------------ scheduler policies

def test_submit_rejects_overlong_prompt(gdn_model):
    """A prompt longer than max_len would wrap the rolling window caches
    mid-prompt and silently corrupt the context — reject at submit."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=32)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(Request(rid=0,
                           prompt=np.arange(1, 40, dtype=np.int32)))
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(rid=1, prompt=np.zeros((0,), np.int32)))
    with pytest.raises(ValueError, match="needs a prompt"):
        eng.submit(Request(rid=2))        # neither prompt nor embeds
    assert not eng.queue                  # nothing was enqueued


def test_run_until_done_strict_raises(gdn_model):
    """Exhausting max_ticks with unfinished work raises (or warns with
    strict=False) instead of silently returning partial results."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(1, 9, dtype=np.int32),
                           max_new_tokens=32))
    with pytest.raises(RuntimeError, match="max_ticks=2 exhausted"):
        eng.run_until_done(max_ticks=2)
    with pytest.warns(RuntimeWarning, match="exhausted"):
        done = eng.run_until_done(max_ticks=1, strict=False)
    assert len(done) < 3
    eng.run_until_done()                  # and it can still finish cleanly
    assert all(r.done for r in eng._all)


def test_free_slots_are_a_deque(gdn_model):
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=3, max_len=32)
    from collections import deque
    assert isinstance(eng.free, deque)


def test_engine_is_scheduler_facade(gdn_model):
    """engine.DecodeEngine is a thin façade: the lifecycle lives in
    Scheduler, the device programs in DeviceExecutor."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=32)
    assert isinstance(eng, Scheduler)
    assert isinstance(eng.executor, DeviceExecutor)
    assert eng.state_bytes_per_slot == eng.executor.state_bytes_per_slot
    assert eng.cache_bytes == eng.executor.cache_bytes


def test_plan_prefill_masked(gdn_model):
    """The default planner emits at most ONE scan shape plus ONE
    fixed-size masked admit chunk per prompt: the compile cache is O(1)
    across all prompt lengths and no prompt ever dispatches more than two
    distinct program shapes."""
    cfg, params = gdn_model
    from repro.serving.executor import PlanStep
    ex = DeviceExecutor(cfg, params, max_slots=1, max_len=256,
                        decode_block=1, prefill_chunk=16)
    assert ex.plan_mode == "masked"
    assert ex.plan_prefill(16) == [PlanStep("admit", 16, 16, 16)]
    assert ex.plan_prefill(17) == [PlanStep("scan", 1, 16, (16,)),
                                   PlanStep("admit", 16, 1, 1)]
    # 75 = 4 full chunks + ragged tail of 11 -> one scan + one masked tail
    assert ex.plan_prefill(75) == [PlanStep("scan", 4, 64, (16,) * 4),
                                   PlanStep("admit", 16, 11, 11)]
    assert ex.plan_prefill(3) == [PlanStep("admit", 16, 3, 3)]
    # scan dispatches are capped so no single program can stall the tick
    # thread for more than _MAX_SCAN_CHUNKS chunks; the trailing dispatch
    # pads with valid_len=0 placeholder chunks instead of a new shape
    assert ex.plan_prefill(256) == \
        [PlanStep("scan", 4, 64, (16,) * 4)] * 3 + \
        [PlanStep("scan", 4, 48, (16, 16, 16, 0)),
         PlanStep("admit", 16, 16, 16)]
    # 5 full chunks balance into 2 dispatches of m=3 (1 placeholder),
    # not 4 + 1 (two shapes)
    assert ex.plan_prefill(5 * 16 + 1) == \
        [PlanStep("scan", 3, 48, (16, 16, 16)),
         PlanStep("scan", 3, 32, (16, 16, 0)),
         PlanStep("admit", 16, 1, 1)]
    shapes_all = set()
    for T in range(1, 257):
        plan = ex.plan_prefill(T)
        shapes = {(s.kind, s.size) for s in plan}
        assert len(shapes) <= 2, (T, plan)      # the tentpole guarantee
        assert sum(s.tokens for s in plan) == T
        shapes_all |= shapes
    assert len(shapes_all) <= 5               # <= 4 scan m's + 1 admit
    with pytest.raises(ValueError, match="empty prompt"):
        ex.plan_prefill(0)


def test_plan_prefill_pow2_baseline(gdn_model):
    """plan_mode="pow2" keeps the PR-3 decomposition (no padding, no
    masking) as the comparison baseline for cold-TTFT / compile counts."""
    cfg, params = gdn_model
    from repro.serving.executor import PlanStep
    ex = DeviceExecutor(cfg, params, max_slots=1, max_len=256,
                        decode_block=1, prefill_chunk=16, plan_mode="pow2")
    assert ex.plan_prefill(16) == [PlanStep("admit", 16, 16)]
    assert ex.plan_prefill(17) == [PlanStep("scan", 1, 16),
                                   PlanStep("admit", 1, 1)]
    assert ex.plan_prefill(75) == [PlanStep("scan", 4, 64),
                                   PlanStep("chunk", 8, 8),
                                   PlanStep("chunk", 2, 2),
                                   PlanStep("admit", 1, 1)]
    assert ex.plan_prefill(256) == [PlanStep("scan", 4, 64)] * 3 + \
        [PlanStep("scan", 2, 32), PlanStep("scan", 1, 16),
         PlanStep("admit", 16, 16)]
    sizes = {s.size for T in range(1, 257) for s in ex.plan_prefill(T)}
    assert len(sizes) <= 10               # bounded program cache
    with pytest.raises(ValueError, match="plan_mode"):
        DeviceExecutor(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, plan_mode="bogus")


def test_prefill_chunk_validation(gdn_model):
    """prefill_chunk is any size >= 1 (no pow2 assumption), but it must
    fit the context buffers — over-long chunks error instead of silently
    clamping."""
    cfg, params = gdn_model
    ex = DeviceExecutor(cfg, params, max_slots=1, max_len=64,
                        decode_block=1, prefill_chunk=7)     # non-pow2 OK
    assert ex.prefill_chunk == 7
    plan = ex.plan_prefill(20)          # 2 full chunks + tail 6
    assert [s.kind for s in plan] == ["scan", "admit"]
    assert sum(s.tokens for s in plan) == 20
    with pytest.raises(ValueError, match="exceeds max_len"):
        DeviceExecutor(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=65)
    with pytest.raises(ValueError, match="prefill_chunk must be >= 1"):
        DeviceExecutor(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=0)
