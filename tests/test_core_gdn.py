"""Core GDN recurrence: fused == naive, chunkwise == sequential, gates, intensity."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gdn, intensity

jax.config.update("jax_enable_x64", False)


def rand(key, *shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype=dtype)


def make_inputs(seed, d_k=32, d_v=32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = rand(ks[0], d_k)
    k = rand(ks[1], d_k)
    v = rand(ks[2], d_v)
    S = rand(ks[3], d_k, d_v) * 0.1
    g = jax.nn.sigmoid(rand(ks[4]))          # in (0,1)
    beta = jax.nn.sigmoid(rand(ks[5]))
    return q, k, v, S, g, beta


# ------------------------------------------------------------------ decode

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("d", [16, 64, 128])
def test_fused_equals_naive(seed, d):
    q, k, v, S, g, beta = make_inputs(seed, d, d)
    o1, S1 = gdn.decode_step_naive(q, k, v, S, g, beta)
    o2, S2 = gdn.decode_step_fused(q, k, v, S, g, beta)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(S1, S2, rtol=2e-5, atol=2e-5)


def test_fused_rectangular_state():
    # d_k != d_v
    q, k, v, S, g, beta = make_inputs(0, d_k=16, d_v=48)
    o1, S1 = gdn.decode_step_naive(q, k, v, S, g, beta)
    o2, S2 = gdn.decode_step_fused(q, k, v, S, g, beta)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(S1, S2, rtol=2e-5, atol=2e-5)


def test_delta_rule_is_error_correcting():
    """Writing (k, v) with beta=1, g=1 makes S^T k retrieve exactly v."""
    q, k, v, S, _, _ = make_inputs(1, 32, 32)
    k = k / jnp.linalg.norm(k)  # unit key -> exact retrieval
    _, S_new = gdn.decode_step_fused(q, k, v, S, jnp.float32(1.0),
                                     jnp.float32(1.0))
    r = S_new.T @ k
    np.testing.assert_allclose(r, v, rtol=1e-4, atol=1e-4)


def test_gates_range_and_formula():
    alpha = jnp.linspace(-4, 4, 9)
    b = jnp.linspace(-4, 4, 9)
    A_log, dt_bias = jnp.float32(0.5), jnp.float32(0.3)
    g, beta = gdn.gates(alpha, b, A_log, dt_bias)
    assert jnp.all(g > 0) and jnp.all(g <= 1)
    assert jnp.all(beta > 0) and jnp.all(beta < 1)
    expected = jnp.exp(-jax.nn.sigmoid(alpha) * jnp.exp(A_log)
                       * jax.nn.softplus(dt_bias))
    np.testing.assert_allclose(g, expected, rtol=1e-6)


# ------------------------------------------------------------------ prefill

def seq_inputs(seed, T, d_k, d_v, strong_gates=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = rand(ks[0], T, d_k)
    # unit keys, as produced by the layer's l2norm (delta-rule stability:
    # beta * ||k||^2 <= 2 keeps S_t = (g - beta k k^T) S_{t-1} + ... non-
    # expansive; raw gaussian keys make the recurrence blow up ~1e16 by
    # T=128 and the fp32 sequential/chunkwise comparison chaotic)
    k = rand(ks[1], T, d_k)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    v = rand(ks[2], T, d_v)
    scale = 5.0 if strong_gates else 1.0
    log_g = -jax.nn.softplus(rand(ks[3], T) * scale)   # log g <= 0
    beta = jax.nn.sigmoid(rand(ks[4], T))
    S0 = rand(ks[5], d_k, d_v) * 0.1
    return q, k, v, log_g, beta, S0


@pytest.mark.parametrize("T,chunk", [(8, 4), (64, 16), (128, 64), (96, 32)])
@pytest.mark.parametrize("delta_rule", [True, False])
def test_chunkwise_equals_sequential(T, chunk, delta_rule):
    q, k, v, log_g, beta, S0 = seq_inputs(2, T, 24, 40)
    O_seq, S_seq = gdn.prefill_sequential(q, k, v, log_g, beta, S0,
                                          delta_rule=delta_rule)
    O_chk, S_chk = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0,
                                         chunk=chunk, delta_rule=delta_rule)
    np.testing.assert_allclose(O_seq, O_chk, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_seq, S_chk, rtol=2e-4, atol=2e-4)


def test_chunkwise_strong_gating_stable():
    """Very strong decay (log g << 0) must not produce inf/nan (log-space)."""
    q, k, v, log_g, beta, S0 = seq_inputs(3, 64, 16, 16, strong_gates=True)
    log_g = log_g * 20.0  # decay factors down to e^-100
    O_seq, S_seq = gdn.prefill_sequential(q, k, v, log_g, beta, S0)
    O_chk, S_chk = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=16)
    assert jnp.all(jnp.isfinite(O_chk))
    np.testing.assert_allclose(O_seq, O_chk, rtol=1e-3, atol=1e-3)


def test_prefill_matches_repeated_decode():
    """Prefill over T tokens == T fused decode steps."""
    T, d = 32, 16
    q, k, v, log_g, beta, S0 = seq_inputs(4, T, d, d)
    O_ref = []
    S = S0
    for t in range(T):
        o, S = gdn.decode_step_fused(q[t], k[t], v[t], S,
                                     jnp.exp(log_g[t]), beta[t])
        O_ref.append(o)
    O_ref = jnp.stack(O_ref)
    O, S_fin = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=8)
    np.testing.assert_allclose(O_ref, O, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S, S_fin, rtol=2e-4, atol=2e-4)


def test_chunkwise_differentiable():
    q, k, v, log_g, beta, S0 = seq_inputs(5, 32, 16, 16)
    # the delta rule is contractive only for ||k|| <= ~sqrt(2/beta): L2-normalize
    # (as real GDN does) so fp32 finite differences are meaningful.
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)

    def loss(q):
        O, _ = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=8)
        return jnp.sum(O ** 2)

    gq = jax.grad(loss)(q)
    assert jnp.all(jnp.isfinite(gq))
    # finite-difference check on one coordinate
    eps = 1e-3
    dq = jnp.zeros_like(q).at[3, 5].set(eps)
    fd = (loss(q + dq) - loss(q - dq)) / (2 * eps)
    np.testing.assert_allclose(gq[3, 5], fd, rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------------ batched / GVA

def test_batched_gva_decode():
    B, Hk, Hv, d = 2, 4, 8, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 6)
    q = rand(ks[0], B, Hk, d)
    k = rand(ks[1], B, Hk, d)
    v = rand(ks[2], B, Hv, d)
    S = rand(ks[3], B, Hv, d, d) * 0.1
    g = jax.nn.sigmoid(rand(ks[4], B, Hv))
    beta = jax.nn.sigmoid(rand(ks[5], B, Hv))
    o, S_new = gdn.gdn_decode(q, k, v, S, g, beta)
    assert o.shape == (B, Hv, d)
    assert S_new.shape == (B, Hv, d, d)
    # GVA: v-head 2*j and 2*j+1 share q/k head j
    o_ref, S_ref = gdn.decode_step_fused(q[1, 2], k[1, 2], v[1, 5],
                                         S[1, 5], g[1, 5], beta[1, 5])
    np.testing.assert_allclose(o[1, 5], o_ref, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(S_new[1, 5], S_ref, rtol=2e-5, atol=2e-5)


def test_batched_prefill_shapes():
    B, T, Hk, Hv, d = 2, 16, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(8), 6)
    q = rand(ks[0], B, T, Hk, d)
    k = rand(ks[1], B, T, Hk, d)
    v = rand(ks[2], B, T, Hv, d)
    log_g = -jax.nn.softplus(rand(ks[3], B, T, Hv))
    beta = jax.nn.sigmoid(rand(ks[4], B, T, Hv))
    S0 = jnp.zeros((B, Hv, d, d))
    O, S = gdn.gdn_prefill(q, k, v, log_g, beta, S0, chunk=8)
    assert O.shape == (B, T, Hv, d)
    assert S.shape == (B, Hv, d, d)
    assert jnp.all(jnp.isfinite(O))


# ------------------------------------------------------------------ intensity model

def test_paper_table2_numbers():
    t2 = intensity.paper_table2()
    # paper: ~4.2 MFLOPs, 2 MB state (x2 round trip -> 4.19 MB naive read paths)
    assert 3.5e6 < t2["gpu"]["flops"] < 5e6
    # GPU naive: 3 reads + 1 write of 2 MB = 8 MB? paper counts 4.2 MB total
    # off-chip I/O (state read + write, 2 MB each) -> our naive model 4 passes.
    assert t2["gpu"]["intensity"] < 1.1         # memory-bound on GPU
    assert t2["ours"]["intensity"] > 50          # compute-bound on-chip (paper: ~88)
    assert t2["ours"]["state_bytes"] == 0.0


def test_fig1_ordering():
    f = intensity.fig1_intensities()
    # paper Fig. 1: GQA ~ 1 FLOP/B; recurrent models below
    assert f["gdn"] < f["mhsa_gqa"] * 1.5
    assert f["mamba2"] < 1.0
    assert f["gdn"] < 1.0
    assert f["gdn_ours_persistent"] > 50
