"""Mixer-registry tests: golden parity vs the pre-refactor implementation,
spec/runtime cache agreement, and the core registry contract — adding a
mixer kind is one module, zero edits to lm.py or the serving engine."""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from pathlib import Path

from repro import configs
from repro.configs.base import ArchConfig
from repro.core import intensity
from repro.models import lm
from repro.models.mixers import (ArraySpec, CacheSpec, MIXERS, SequenceMixer,
                                 get_mixer, register)
from repro.serving.engine import DecodeEngine, Request

GOLDEN = Path(__file__).parent / "golden" / "mixer_parity.npz"

# one arch per pattern kind: attn, swa, gdn(+attn), ssm, rglru(+swa)
PARITY_ARCHS = ["yi-9b", "h2o-danube-1.8b", "qwen3-next-gdn", "mamba2-1.3b",
                "recurrentgemma-2b"]


def _rollout(cfg, B=2, T=8):
    """The exact computation the goldens were dumped with (seed tree,
    tests/golden/README.md)."""
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0,
                                cfg.vocab)
    caches = lm.init_caches(cfg, B, max_len=32)
    logits_p, caches = lm.prefill(params, cfg, caches, tokens=tokens[:, :T])
    logits_d, _ = lm.decode_step(params, cfg, tokens[:, T], caches)
    return np.asarray(logits_p), np.asarray(logits_d)


# ------------------------------------------------------------------ parity

@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_golden_parity_vs_pre_refactor(arch):
    """prefill + decode_step logits are bitwise identical to the dispatch-
    chain implementation the registry replaced (goldens dumped at the seed
    commit)."""
    golden = np.load(GOLDEN)
    logits_p, logits_d = _rollout(configs.get_arch(arch).reduced())
    np.testing.assert_array_equal(logits_p, golden[f"{arch}/prefill"])
    np.testing.assert_array_equal(logits_d, golden[f"{arch}/decode"])


def test_gdn_naive_matches_fused():
    """The sixth registered kind (Alg. 1 three-pass reference) reproduces
    the fused Alg. 2 datapath through the full model."""
    cfg = configs.get_arch("qwen3-next-gdn").reduced().replace(
        pattern=("gdn",), n_layers=2)
    logits_p, logits_d = _rollout(cfg)
    # same params (gdn_naive inherits init_params), different decode path
    naive_p, naive_d = _rollout(cfg.replace(pattern=("gdn_naive",)))
    np.testing.assert_array_equal(logits_p, naive_p)   # prefill identical
    np.testing.assert_allclose(logits_d, naive_d, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------ specs

@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_cache_spec_matches_runtime(arch):
    """The declarative spec and the runtime caches are the same pytree:
    identical structure, shapes and dtypes — the contract the serving
    engine's slot buffers and byte budgets are built on."""
    cfg = configs.get_arch(arch).reduced()
    spec = lm.cache_specs(cfg, 2, 32)
    caches = lm.init_caches(cfg, 2, 32)
    sds = spec.shape_dtype()
    assert (jax.tree.structure(sds, is_leaf=lambda x: x is None)
            == jax.tree.structure(caches, is_leaf=lambda x: x is None))
    for s, c in zip(jax.tree.leaves(sds), jax.tree.leaves(caches)):
        assert s.shape == c.shape and s.dtype == c.dtype
    # decode preserves the spec'd layout
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    _, caches2 = lm.decode_step(params, cfg, jnp.zeros((2,), jnp.int32),
                                caches)
    for s, c in zip(jax.tree.leaves(sds), jax.tree.leaves(caches2)):
        assert s.shape == c.shape and s.dtype == c.dtype


def test_state_byte_roles():
    """role bookkeeping: pure softmax attention has window (KV) bytes but no
    fixed persistent state; subquadratic archs are the opposite."""
    attn = configs.get_arch("yi-9b").reduced()
    assert lm.cache_specs(attn, 1, 64).state_bytes == 0
    assert lm.cache_specs(attn, 1, 64).window_bytes > 0
    assert intensity.arch_state_bytes(attn) == 0
    ssm = configs.get_arch("mamba2-1.3b").reduced()
    assert lm.cache_specs(ssm, 1, 64).window_bytes == 0
    assert lm.cache_specs(ssm, 1, 64).state_bytes > 0
    # intensity model and serving engine derive from the same spec
    params = lm.init_lm(jax.random.PRNGKey(0), ssm)
    eng = DecodeEngine(ssm, params, max_slots=2, max_len=64)
    assert eng.state_bytes_per_slot == intensity.arch_state_bytes(ssm)


# ------------------------------------------------------- registry contract

class _EMA(SequenceMixer):
    """Toy diagonal-EMA mixer used only by the registry-extension test:
    h <- a * h + (1 - a) * (x W_in), out = h W_out."""
    kind = "test_ema"
    state_passes = 2

    @classmethod
    def init_params(cls, key, cfg, dtype):
        k1, k2 = jax.random.split(key)
        d = cfg.d_model
        s = d ** -0.5
        return {"w_in": (jax.random.normal(k1, (d, d)) * s).astype(dtype),
                "w_out": (jax.random.normal(k2, (d, d)) * s).astype(dtype),
                "log_a": jnp.zeros((d,), jnp.float32)}

    @classmethod
    def _step(cls, params, h, x_t):
        a = jax.nn.sigmoid(params["log_a"])
        u = (x_t.astype(jnp.float32) @ params["w_in"].astype(jnp.float32))
        h = a * h + (1.0 - a) * u
        return h, (h @ params["w_out"].astype(jnp.float32)).astype(x_t.dtype)

    @classmethod
    def train(cls, params, cfg, x):
        out, _ = cls.prefill(params, cfg, x, {"h": jnp.zeros(
            (x.shape[0], cfg.d_model), jnp.float32)})
        return out

    @classmethod
    def prefill(cls, params, cfg, x, cache):
        def scan_step(h, x_t):
            h, o = cls._step(params, h, x_t)
            return h, o
        h, out = jax.lax.scan(scan_step, cache["h"], x.swapaxes(0, 1))
        return out.swapaxes(0, 1), {"h": h}

    @classmethod
    def decode(cls, params, cfg, x_t, cache):
        h, o = cls._step(params, cache["h"], x_t)
        return o, {"h": h}

    @classmethod
    def cache_spec(cls, cfg, batch, max_len):
        return CacheSpec({"h": ArraySpec((batch, cfg.d_model), jnp.float32,
                                         "state")})

    @classmethod
    def decode_flops(cls, cfg, seq):
        return 4.0 * cfg.d_model ** 2

    @classmethod
    def decode_token_bytes(cls, cfg):
        return 2 * cfg.d_model * jnp.dtype(cfg.act_dtype).itemsize

    @classmethod
    def param_count(cls, cfg):
        return 2 * cfg.d_model ** 2 + cfg.d_model


@pytest.fixture
def ema_registered():
    register(_EMA)
    yield
    MIXERS.pop(_EMA.kind, None)


def test_register_new_kind_no_lm_or_engine_edit(ema_registered):
    """A kind registered from outside the package trains, prefills, decodes
    and *serves* through completely untouched lm.py / engine.py — the
    tentpole claim."""
    cfg = ArchConfig(name="toy-ema", family="ssm", vocab=64, d_model=32,
                     n_layers=3, pattern=("test_ema",), ffn="dense",
                     d_ff=64, act_dtype="float32", remat=False,
                     subquadratic=True)
    assert get_mixer("test_ema") is _EMA
    # lm.py has no per-kind dispatch left to edit
    src = inspect.getsource(lm)
    assert "kind ==" not in src and "test_ema" not in src
    # train path
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    loss, _ = lm.loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))
    # cached path agrees with itself across the prefill/decode boundary
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 9), 0, cfg.vocab)
    caches = lm.init_caches(cfg, 2, max_len=32)
    la, _ = lm.prefill(params, cfg, caches, tokens=tokens)
    caches = lm.init_caches(cfg, 2, max_len=32)
    _, caches = lm.prefill(params, cfg, caches, tokens=tokens[:, :8])
    lb, _ = lm.decode_step(params, cfg, tokens[:, 8], caches)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-4, atol=2e-4)
    # serves through the untouched engine (spec-driven slot buffers)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=32)
    assert eng.state_bytes_per_slot == cfg.n_layers * 4 * cfg.d_model
    reqs = [Request(rid=i, prompt=np.arange(1, 5 + i, dtype=np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert len(done) == 3 and all(len(r.output) == 3 for r in reqs)


def test_builtin_kinds_registered():
    assert {"attn", "swa", "gdn", "ssm", "rglru",
            "gdn_naive"} <= set(MIXERS)
    with pytest.raises(KeyError, match="unknown mixer kind"):
        get_mixer("nope")


# ------------------------------------------------------------------ engine

def test_engine_max_new_tokens_one_no_extra_decode():
    """A max_new_tokens=1 request completes at admit with exactly one token
    and never occupies a decode slot (the admit-time off-by-one)."""
    cfg = configs.get_arch("mamba2-1.3b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64)
    req = Request(rid=0, prompt=np.arange(1, 8, dtype=np.int32),
                  max_new_tokens=1)
    eng.submit(req)
    done = eng.run_until_done()
    assert done == [req] and req.output and len(req.output) == 1
    assert eng.ticks == 0                      # no batched decode ran
    assert sorted(eng.free) == [0, 1]          # no slot was ever consumed


def test_engine_eos_at_admit():
    """EOS produced by the admit-time prefill completes the request
    immediately instead of decoding until max_new_tokens."""
    cfg = configs.get_arch("mamba2-1.3b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    prompt = np.arange(1, 8, dtype=np.int32)
    # find the greedy admit-time token, then use it as the EOS id
    caches = lm.init_caches(cfg, 1, 64)
    logits, _ = lm.prefill(params, cfg, caches,
                           tokens=jnp.asarray(prompt)[None])
    eos = int(jnp.argmax(logits[0]))
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=10, eos_id=eos)
    eng.submit(req)
    eng.run_until_done()
    assert req.done and req.output == [eos]
    assert eng.ticks == 0
