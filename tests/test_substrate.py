"""Substrate tests: data pipeline, optimizer, checkpointing, trainer
fault-tolerance, serving engine."""
import logging
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import manager as ckpt
from repro.data.pipeline import DataConfig, HostDataLoader, pack_documents
from repro.models import lm
from repro.optim import optimizers as opt
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.serving.engine import DecodeEngine, Request


# ------------------------------------------------------------------ data

def test_loader_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    a = HostDataLoader(cfg, host_index=0, host_count=2)
    b = HostDataLoader(cfg, host_index=1, host_count=2)
    ba0 = a.batch_at(3)
    ba1 = a.batch_at(3)
    np.testing.assert_array_equal(ba0["tokens"], ba1["tokens"])  # replayable
    assert ba0["tokens"].shape == (4, 64)
    assert not np.array_equal(ba0["tokens"], b.batch_at(3)["tokens"])
    # labels are next-token shifted
    rows = a._rows_for_step(3)
    np.testing.assert_array_equal(ba0["labels"], rows[:, 1:])


def test_packing_exact_rows():
    docs = iter([np.arange(1, 10, dtype=np.int32)] * 20)
    rows = list(pack_documents(docs, seq_len=16, eos_id=0))
    assert all(r.shape == (17,) for r in rows)
    flat = np.concatenate(rows)
    assert (flat == 0).sum() >= len(rows)  # separators present


def test_prefetch_thread():
    cfg = DataConfig(vocab=100, seq_len=32, global_batch=2)
    l = HostDataLoader(cfg)
    l.start(start_step=5)
    s, b = l.next()
    assert s == 5 and b["tokens"].shape == (2, 32)


# ------------------------------------------------------------------ optim

def test_wsd_schedule_phases():
    lr = opt.wsd_schedule(1.0, warmup_steps=10, stable_steps=80,
                          decay_steps=10)
    assert float(lr(0)) == 0.0
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(50)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-6)


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([2.0, -3.0, 1.5])}
    cfg = opt.AdamWConfig(weight_decay=0.0, clip_norm=100.0)
    state = opt.init_adamw(params, cfg)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.adamw_update(grads, state, params, 0.05, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_adamw_bf16_moments_with_error_feedback():
    params = {"w": jnp.ones((64,))}
    cfg = opt.AdamWConfig(moment_dtype="bfloat16", error_feedback=True,
                          weight_decay=0.0)
    state = opt.init_adamw(params, cfg)
    assert state["mu"]["w"]["m"].dtype == jnp.bfloat16
    assert "ef" in state["mu"]["w"]
    grads = {"w": jnp.full((64,), 1e-3)}
    p2, state, _ = opt.adamw_update(grads, state, params, 0.01, cfg)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_adamw_factored_second_moment():
    params = {"w": jnp.ones((32, 16))}
    cfg = opt.AdamWConfig(factored=True, weight_decay=0.0)
    state = opt.init_adamw(params, cfg)
    assert state["mu"]["w"]["v_row"].shape == (32,)
    assert state["mu"]["w"]["v_col"].shape == (16,)
    grads = {"w": jnp.ones((32, 16)) * 0.1}
    p2, state, _ = opt.adamw_update(grads, state, params, 0.01, cfg)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0, 4.0])}       # norm 5
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               [0.6, 0.8], rtol=1e-5)


# ------------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": [jnp.ones(4), {"c": jnp.zeros((2, 2), jnp.int32)}]}
    ckpt.save(tree, str(tmp_path), 7)
    out = ckpt.restore(tree, str(tmp_path), 7)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))
        assert x.dtype == y.dtype


def test_checkpoint_manager_gc_and_latest(tmp_path):
    m = ckpt.CheckpointManager(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros(3)}
    for s in (10, 20, 30):
        m.save(tree, s)
    assert m.latest_step() == 30
    assert ckpt.completed_steps(str(tmp_path)) == [20, 30]


def test_checkpoint_skips_partial(tmp_path):
    m = ckpt.CheckpointManager(str(tmp_path))
    tree = {"x": jnp.zeros(3)}
    m.save(tree, 10)
    # simulate a crashed save: directory without manifest
    os.makedirs(tmp_path / "step_000000020")
    assert m.latest_step() == 10


# ------------------------------------------------------------------ trainer

def _tiny_trainer(tmp_path, **kw):
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    tc = TrainerConfig(steps=6, seq_len=32, global_batch=2, peak_lr=1e-3,
                       warmup_steps=2, ckpt_dir=str(tmp_path),
                       ckpt_every=2, ckpt_async=False, log_every=2, **kw)
    return Trainer(cfg, tc)


def test_trainer_loss_decreases(tmp_path):
    t = _tiny_trainer(tmp_path)
    hist = t.run()
    assert len(hist) >= 2
    assert hist[-1][0] == 6


def test_trainer_failure_recovery(tmp_path, caplog):
    t = _tiny_trainer(tmp_path)
    with caplog.at_level(logging.WARNING):
        hist = t.run(fail_at=4)
    assert t.restarts == 1
    assert hist[-1][0] == 6                       # completed despite fault
    assert any("restoring" in r.message for r in caplog.records)


def test_trainer_resume_from_checkpoint(tmp_path):
    t = _tiny_trainer(tmp_path)
    t.run()
    # new trainer instance resumes at latest step and does nothing more
    t2 = _tiny_trainer(tmp_path)
    t2.compile()
    start = t2._maybe_restore()
    assert start == 6


def test_trainer_microbatch_accumulation(tmp_path):
    cfg = configs.get_arch("minicpm-2b").reduced()
    tc = TrainerConfig(steps=2, seq_len=32, global_batch=4, microbatches=2,
                       ckpt_dir=None, schedule="wsd")
    t = Trainer(cfg, tc)
    hist = t.run()
    assert hist[-1][0] == 2


# ------------------------------------------------------------------ serving

def test_engine_continuous_batching():
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                    max_new_tokens=4 + i) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert len(done) == 5
    assert all(len(r.output) == r.max_new_tokens for r in reqs)
    # 5 requests through 2 slots => continuous batching reused slots
    assert eng.ticks < sum(r.max_new_tokens for r in reqs)


def test_engine_matches_unbatched_decode():
    """Greedy output through the engine == straight prefill+decode loop."""
    cfg = configs.get_arch("mamba2-1.3b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(1), cfg)
    prompt = np.arange(1, 9, dtype=np.int32)
    n_new = 5

    caches = lm.init_caches(cfg, 1, 64)
    logits, caches = lm.prefill(params, cfg, caches,
                                tokens=jnp.asarray(prompt)[None])
    ref = [int(jnp.argmax(logits[0]))]
    for _ in range(n_new - 1):
        logits, caches = lm.decode_step(
            params, cfg, jnp.asarray([ref[-1]], jnp.int32), caches)
        ref.append(int(jnp.argmax(logits[0])))

    eng = DecodeEngine(cfg, params, max_slots=3, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new_tokens=n_new)
    eng.submit(req)
    eng.run_until_done()
    assert req.output == ref


def test_engine_stub_frontend_embeds():
    """VLM/audio archs: prompt is precomputed embeddings (stub frontend),
    generation continues from token ids."""
    cfg = configs.get_arch("musicgen-medium").reduced()
    params = lm.init_lm(jax.random.PRNGKey(3), cfg)
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt_embeds=rng.normal(size=(6, cfg.d_model))
                    .astype(np.float32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_done()
    assert len(done) == 3
    assert all(len(r.output) == 4 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.output)


def test_pallas_serving_path_matches_xla():
    """use_pallas_serving=True routes prefill/decode through the fused
    persistent-state Pallas kernels (interpret mode on CPU) and reproduces
    the XLA path bit-closely — the paper's kernel as a first-class serving
    feature."""
    for arch in ("qwen3-next-gdn", "mamba2-1.3b"):
        cfg = configs.get_arch(arch).reduced()
        cfg_p = cfg.replace(use_pallas_serving=True)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        B, T = 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T + 3), 0,
                                    cfg.vocab)

        def rollout(c):
            caches = lm.init_caches(c, B, max_len=64)
            logits, caches = lm.prefill(params, c, caches,
                                        tokens=tokens[:, :T])
            outs = [logits]
            for t in range(3):
                logits, caches = lm.decode_step(params, c, tokens[:, T + t],
                                                caches)
                outs.append(logits)
            return jnp.stack(outs)

        lo_x = rollout(cfg)
        lo_p = rollout(cfg_p)
        np.testing.assert_allclose(np.asarray(lo_x), np.asarray(lo_p),
                                   rtol=2e-3, atol=2e-3)
