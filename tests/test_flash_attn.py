"""Flash-attention train kernel vs dense oracle — values AND gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention


def dense_ref(q, k, v, window=None):
    B, T, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, T, Hkv, G, hd)
    s = jnp.einsum("bthgd,bshd->bhgts", qf, k.astype(jnp.float32))
    s = s * hd ** -0.5
    pos_q = jnp.arange(T)[:, None]
    pos_k = jnp.arange(T)[None, :]
    mask = pos_q >= pos_k
    if window is not None:
        mask &= (pos_q - pos_k) < window
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgts,bshd->bthgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, Hq, hd).astype(q.dtype)


def make(seed, B, T, Hq, Hkv, hd, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, T, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, T, Hkv, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,T,Hq,Hkv,hd,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),      # MHA
    (2, 128, 8, 2, 32, 32, 64),      # GQA 4:1, uneven blocks
    (1, 256, 4, 1, 64, 128, 128),    # MQA
])
def test_flash_fwd_matches_dense(B, T, Hq, Hkv, hd, bq, bk):
    q, k, v = make(0, B, T, Hq, Hkv, hd)
    o = flash_attention(q, k, v, bq, bk, None, True)
    o_ref = dense_ref(q, k, v)
    np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)


def test_flash_fwd_sliding_window():
    q, k, v = make(1, 1, 256, 4, 2, 32)
    o = flash_attention(q, k, v, 64, 64, 64, True)
    o_ref = dense_ref(q, k, v, window=64)
    np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [None, 64])
def test_flash_grads_match_dense(window):
    q, k, v = make(2, 1, 128, 4, 2, 32)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, 64, 64, window, True) ** 2)

    def loss_dense(q, k, v):
        return jnp.sum(dense_ref(q, k, v, window=window) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gd, "qkv"):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3,
                                   err_msg=f"d{name}")


def test_flash_bf16():
    q, k, v = make(3, 1, 128, 4, 4, 64, jnp.bfloat16)
    o = flash_attention(q, k, v, 64, 64, None, True)
    o_ref = dense_ref(q, k, v)
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_ref.astype(jnp.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_path_through_model():
    """use_flash_kernel=True trains a reduced attention arch end to end
    (interpret mode on CPU) and matches the XLA path."""
    from repro import configs
    from repro.models import lm
    cfg = configs.get_arch("yi-9b").reduced()
    cfg_f = cfg.replace(use_flash_kernel=True)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, T = 1, 64
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                     cfg.vocab),
    }
    (l_x, _), g_x = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, cfg, batch)
    (l_f, _), g_f = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, cfg_f, batch)
    np.testing.assert_allclose(float(l_x), float(l_f), rtol=1e-3)
    for a, b in zip(jax.tree.leaves(g_x), jax.tree.leaves(g_f)):
        np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)
