"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
output shapes + finiteness; prefill->decode consistency for the cached path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm

ALL_ARCHS = sorted(configs.ARCHS)


def make_batch(cfg, key, B=2, T=32):
    kt, kl, ke = jax.random.split(key, 3)
    batch = {"labels": jax.random.randint(kl, (B, T), 0, cfg.vocab)}
    if cfg.frontend_stub:
        batch["embeds"] = jax.random.normal(ke, (B, T, cfg.d_model),
                                            jnp.dtype(cfg.act_dtype))
    else:
        batch["tokens"] = jax.random.randint(kt, (B, T), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = configs.get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_lm(key, cfg)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    (loss, metrics), grads = jax.value_and_grad(
        lm.loss_fn, has_aux=True)(params, cfg, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), \
        f"{arch}: non-finite grads"
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype),
                           params, grads)
    loss2, _ = lm.loss_fn(params2, cfg, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(t) after prefill(0..t-1) == prefill(0..t) last logits."""
    cfg = configs.get_arch(arch).reduced()
    if cfg.frontend_stub:
        pytest.skip("stub-frontend archs decode from token ids after a "
                    "prompt embedding prefill; covered in serving tests")
    if cfg.moe_experts:
        # dropless capacity (cf >= E/k) so the capacity-dispatch prefill is
        # exactly comparable with the exact decode path
        cfg = cfg.replace(moe_capacity_factor=float(cfg.moe_experts)
                          / cfg.moe_top_k)
    B, T = 2, 16
    key = jax.random.PRNGKey(2)
    params = lm.init_lm(key, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, T + 1), 0,
                                cfg.vocab)

    caches_a = lm.init_caches(cfg, B, max_len=64)
    logits_a, _ = lm.prefill(params, cfg, caches_a, tokens=tokens)

    caches_b = lm.init_caches(cfg, B, max_len=64)
    _, caches_b = lm.prefill(params, cfg, caches_b, tokens=tokens[:, :T])
    logits_b, _ = lm.decode_step(params, cfg, tokens[:, T], caches_b)

    np.testing.assert_allclose(np.asarray(logits_a), np.asarray(logits_b),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["qwen3-next-gdn", "mamba2-1.3b",
                                  "recurrentgemma-2b", "h2o-danube-1.8b"])
def test_subquadratic_decode_beyond_cache(arch):
    """Sub-quadratic archs keep decoding past the rolling-window size."""
    cfg = configs.get_arch(arch).reduced()
    B = 2
    params = lm.init_lm(jax.random.PRNGKey(4), cfg)
    # window reduced to 32; cache sized at the window => unbounded decode
    caches = lm.init_caches(cfg, B, max_len=40)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(4):
        logits, caches = lm.decode_step(params, cfg, tok, caches)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_train_matches_cached_path():
    """forward_hidden (train path) logits == prefill (cached path) logits."""
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    B, T = 1, 8
    params = lm.init_lm(jax.random.PRNGKey(5), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, T), 0, cfg.vocab)
    h, _ = lm.forward_hidden(params, cfg, tokens=tokens)
    from repro.models import layers as L
    h_last = L.rmsnorm_fwd(params["final_norm"], h[:, -1], cfg.norm_eps)
    logits_train = lm._logits(params, cfg, h_last)
    caches = lm.init_caches(cfg, B, max_len=32)
    logits_pre, _ = lm.prefill(params, cfg, caches, tokens=tokens)
    np.testing.assert_allclose(np.asarray(logits_train),
                               np.asarray(logits_pre), rtol=2e-3, atol=2e-3)


def test_head_padding_exact():
    """TP head padding (zero weights + output mask) must be a no-op on the
    model function: padded and unpadded configs agree when the real-head
    weights coincide."""
    cfg = configs.get_arch("recurrentgemma-2b").reduced()
    cfg = cfg.replace(n_heads=3, n_kv_heads=1, head_dim=16)
    cfg_pad = cfg.replace(n_heads_pad=4)
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    params_pad = lm.init_lm(jax.random.PRNGKey(0), cfg_pad)

    # copy the real heads' weights into the padded layout
    def graft(p, p_pad):
        for g, (gp, gpp) in enumerate(zip(p["groups"], p_pad["groups"])):
            for pos, (lp, lpp) in enumerate(zip(gp, gpp)):
                m = lp["mixer"]
                if "wq" not in m:
                    lpp["mixer"] = m
                    continue
                # stacked layouts: wq (reps, D, Hpad, hd), wo (reps, Hpad,
                # hd, D)
                mp = dict(lpp["mixer"])
                mp["wq"] = lpp["mixer"]["wq"].at[:, :, :3, :].set(m["wq"])
                mp["wk"], mp["wv"] = m["wk"], m["wv"]
                mp["wo"] = lpp["mixer"]["wo"].at[:, :3].set(m["wo"])
                lpp["mixer"] = mp
        p_pad["embed"] = p["embed"]
        p_pad["final_norm"] = p["final_norm"]
        if "lm_head" in p:
            p_pad["lm_head"] = p["lm_head"]
        return p_pad

    params_pad = graft(params, params_pad)
    B, T = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    h, _ = lm.forward_hidden(params, cfg, tokens=tokens)
    hp, _ = lm.forward_hidden(params_pad, cfg_pad, tokens=tokens)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hp),
                               rtol=2e-4, atol=2e-4)
