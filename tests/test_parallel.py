"""Sharding rules + multi-device (8 fake CPU devices, subprocess) tests."""
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.parallel import sharding


def small_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_fit_spec_drops_and_rebalances():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # use a fake 16x16 mesh via axis sizes: emulate with real mesh of 1s —
    # fit_spec only consults axis sizes, so build the spec logic directly.
    # Here sizes are 1 => everything divides; use the 512-device mesh in the
    # subprocess test below for the real thing.
    spec = sharding.fit_spec(P("model", None), (7, 16), mesh)
    assert spec == P("model", None)


def test_param_specs_cover_all_archs():
    mesh = small_mesh()
    for name in configs.ARCHS:
        cfg = configs.get_arch(name).reduced()
        from repro.models import lm
        shapes = jax.eval_shape(lambda k: lm.init_lm(k, cfg),
                                jax.random.PRNGKey(0))
        specs = sharding.params_specs(cfg, shapes, False, mesh)
        flat_sh = jax.tree_util.tree_leaves(shapes)
        flat_sp = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_sh) == len(flat_sp)
        for sh, sp in zip(flat_sh, flat_sp):
            assert len(sp) <= len(sh.shape), (name, sh.shape, sp)


def test_estimate_params_plausible():
    est = sharding.estimate_params(configs.get_arch("yi-9b"))
    assert 8e9 < est < 10e9
    est = sharding.estimate_params(configs.get_arch("arctic-480b"))
    assert 4e11 < est < 5.5e11
    est = sharding.estimate_params(configs.get_arch("mamba2-1.3b"))
    assert 0.9e9 < est < 1.8e9


def test_needs_fsdp_thresholds():
    import numpy as np
    from jax.sharding import Mesh
    devs = np.array(jax.devices())[:1].reshape(1, 1)
    mesh = Mesh(devs, ("data", "model"))
    # force axis sizes via a fake object is overkill — check the math:
    n = sharding.estimate_params(configs.get_arch("arctic-480b"))
    assert n * 14 / 16 > 10e9           # would need fsdp on a 16-way TP


SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.configs.base import SHAPES, ShapeConfig
    from repro.launch import steps as steps_mod
    from repro.runtime import trainer as trainer_mod
    from repro.parallel import sharding

    mesh = jax.make_mesh((4, 2), ("data", "model"))

    # --- 1. a real sharded train step on 8 devices, small shape
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    shape = ShapeConfig("tiny_train", 64, 8, "train")
    fn, args, in_sh, out_sh, donate = steps_mod.build_cell(cfg, shape, mesh)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    txt = compiled.as_text()
    assert any(c in txt for c in ("all-reduce", "all-gather")), "no collectives?"

    # --- 2. run it for real: state materialized with the same shardings
    key = jax.random.PRNGKey(0)
    tc = trainer_mod.TrainerConfig(steps=2, seq_len=64, global_batch=8)
    with mesh:
        state = trainer_mod.init_state(key, cfg, tc)
        state = jax.device_put(state, in_sh[0])
        batch = {
            "tokens": jnp.zeros((8, 64), jnp.int32),
            "labels": jnp.zeros((8, 64), jnp.int32),
        }
        batch = jax.device_put(batch, in_sh[1])
        state2, metrics = jitted(state, batch)
        assert np.isfinite(float(metrics["loss"]))

    # --- 3. serve step sharded decode
    dshape = ShapeConfig("tiny_decode", 64, 8, "decode")
    fn, args, in_sh, out_sh, donate = steps_mod.build_cell(cfg, dshape, mesh)
    with mesh:
        co = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=donate).lower(*args).compile()
    print("SUBPROCESS_OK")
""")


def test_multidevice_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=600)
    assert "SUBPROCESS_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-4000:]
