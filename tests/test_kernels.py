"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)


def gdn_inputs(seed, B, Hk, Hv, d_k, d_v, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, Hk, d_k), dtype)
    k = jax.random.normal(ks[1], (B, Hk, d_k), dtype)
    k = k / jnp.linalg.norm(k.astype(jnp.float32), axis=-1,
                            keepdims=True).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hv, d_v), dtype)
    S = (jax.random.normal(ks[3], (B, Hv, d_k, d_v)) * 0.2).astype(jnp.float32)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, Hv)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[5], (B, Hv)))
    return q, k, v, S, g, beta


# ----------------------------------------------------------------- gdn_decode

@pytest.mark.parametrize("head_block", [2, 4, 8, 16])
def test_gdn_decode_head_block_sweep(head_block):
    """The paper's H_iter knob: all head blockings give identical results."""
    q, k, v, S, g, beta = gdn_inputs(0, B=2, Hk=8, Hv=16, d_k=128, d_v=128)
    o, S_new = ops.gdn_decode(q, k, v, S, g, beta, head_block=head_block)
    o_ref, S_ref = ref.gdn_decode_ref(q, k, v, S, g, beta)
    np.testing.assert_allclose(o, o_ref, **tol(q.dtype))
    np.testing.assert_allclose(S_new, S_ref, **tol(q.dtype))


@pytest.mark.parametrize("B,Hk,Hv,d_k,d_v", [
    (1, 1, 1, 128, 128),
    (1, 16, 32, 128, 128),     # the paper's Qwen3-Next layer config
    (4, 2, 4, 64, 64),
    (2, 4, 4, 128, 64),        # R=1, rectangular (mamba2-like)
])
def test_gdn_decode_shapes(B, Hk, Hv, d_k, d_v):
    q, k, v, S, g, beta = gdn_inputs(1, B, Hk, Hv, d_k, d_v)
    hb = min(8, Hv)
    o, S_new = ops.gdn_decode(q, k, v, S, g, beta, head_block=hb)
    o_ref, S_ref = ref.gdn_decode_ref(q, k, v, S, g, beta)
    np.testing.assert_allclose(o, o_ref, **tol(q.dtype))
    np.testing.assert_allclose(S_new, S_ref, **tol(q.dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gdn_decode_dtypes(dtype):
    q, k, v, S, g, beta = gdn_inputs(2, 2, 4, 8, 128, 128, dtype)
    o, S_new = ops.gdn_decode(q, k, v, S, g, beta, head_block=4)
    o_ref, S_ref = ref.gdn_decode_ref(q, k, v, S, g, beta)
    assert o.dtype == dtype
    assert S_new.dtype == jnp.float32          # state stays fp32 (paper)
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_ref.astype(jnp.float32), **tol(dtype))
    np.testing.assert_allclose(S_new, S_ref, **tol(dtype))


def test_gdn_decode_ssd_mode():
    """delta_rule=False == mamba2/SSD decode update."""
    q, k, v, S, g, _ = gdn_inputs(3, 2, 4, 4, 128, 64)
    o, S_new = ops.gdn_decode(q, k, v, S, g, g, head_block=4,
                              delta_rule=False)
    o_ref, S_ref = ref.gdn_decode_ref(q, k, v, S, g, g, delta_rule=False)
    np.testing.assert_allclose(o, o_ref, **tol(q.dtype))
    np.testing.assert_allclose(S_new, S_ref, **tol(q.dtype))


def test_gdn_decode_multi_token_trajectory():
    """Kernel applied T times == sequential oracle over T tokens (state
    persistence across invocations is exact)."""
    B, Hk, Hv, d = 1, 2, 4, 64
    T = 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    qs = jax.random.normal(ks[0], (T, B, Hk, d))
    kk = jax.random.normal(ks[1], (T, B, Hk, d))
    kk = kk / jnp.linalg.norm(kk, axis=-1, keepdims=True)
    vs = jax.random.normal(ks[2], (T, B, Hv, d))
    gs = jax.nn.sigmoid(jax.random.normal(ks[3], (T, B, Hv)))
    bs = jax.nn.sigmoid(jax.random.normal(ks[4], (T, B, Hv)))
    S = jnp.zeros((B, Hv, d, d))
    S_ref = S
    for t in range(T):
        o, S = ops.gdn_decode(qs[t], kk[t], vs[t], S, gs[t], bs[t],
                              head_block=4)
        o_r, S_ref = ref.gdn_decode_ref(qs[t], kk[t], vs[t], S_ref,
                                        gs[t], bs[t])
        np.testing.assert_allclose(o, o_r, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S, S_ref, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- gdn_prefill

def prefill_inputs(seed, B, T, Hk, Hv, d_k, d_v, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, T, Hk, d_k), dtype)
    k = jax.random.normal(ks[1], (B, T, Hk, d_k), dtype)
    k = k / jnp.linalg.norm(k.astype(jnp.float32), axis=-1,
                            keepdims=True).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, Hv, d_v), dtype)
    log_g = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, Hv)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[4], (B, T, Hv)))
    S0 = (jax.random.normal(ks[5], (B, Hv, d_k, d_v)) * 0.1)
    return q, k, v, log_g, beta, S0


@pytest.mark.parametrize("T,chunk", [(32, 8), (64, 16), (64, 64)])
@pytest.mark.parametrize("delta_rule", [True, False])
def test_gdn_prefill_vs_sequential(T, chunk, delta_rule):
    q, k, v, log_g, beta, S0 = prefill_inputs(7, 2, T, 2, 4, 32, 32)
    O, S = ops.gdn_prefill(q, k, v, log_g, beta, S0, chunk=chunk,
                           delta_rule=delta_rule)
    # oracle works on (BH, T, d) layout
    B, _, Hk, d_k = q.shape
    Hv = v.shape[2]
    R = Hv // Hk
    qh = jnp.repeat(q.transpose(0, 2, 1, 3), R, 1).reshape(B * Hv, T, d_k)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), R, 1).reshape(B * Hv, T, d_k)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hv, T, -1)
    lgh = log_g.transpose(0, 2, 1).reshape(B * Hv, T)
    bh = beta.transpose(0, 2, 1).reshape(B * Hv, T)
    S0h = S0.reshape(B * Hv, d_k, -1)
    O_ref, S_ref = ref.gdn_prefill_ref(qh, kh, vh, lgh, bh, S0h,
                                       delta_rule=delta_rule)
    O_ref = O_ref.reshape(B, Hv, T, -1).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(O, O_ref, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S.reshape(S0h.shape), S_ref,
                               rtol=5e-4, atol=5e-4)


def test_gdn_prefill_then_decode_consistency():
    """Prefill kernel state handoff feeds the decode kernel correctly."""
    B, T, Hk, Hv, d = 1, 32, 2, 4, 32
    q, k, v, log_g, beta, S0 = prefill_inputs(9, B, T, Hk, Hv, d, d)
    S0 = jnp.zeros_like(S0)
    O, S = ops.gdn_prefill(q, k, v, log_g, beta, S0, chunk=8)
    # one more decode token via the decode kernel
    o2, S2 = ops.gdn_decode(q[:, 0], k[:, 0], v[:, 0], S,
                            jnp.exp(log_g[:, 0]), beta[:, 0], head_block=4)
    # oracle: sequential over T+1 tokens
    qh = jnp.concatenate([q, q[:, :1]], 1)
    kh = jnp.concatenate([k, k[:, :1]], 1)
    vh = jnp.concatenate([v, v[:, :1]], 1)
    lgh = jnp.concatenate([log_g, log_g[:, :1]], 1)
    bh = jnp.concatenate([beta, beta[:, :1]], 1)
    R = Hv // Hk
    qr = jnp.repeat(qh.transpose(0, 2, 1, 3), R, 1).reshape(B * Hv, T + 1, d)
    kr = jnp.repeat(kh.transpose(0, 2, 1, 3), R, 1).reshape(B * Hv, T + 1, d)
    vr = vh.transpose(0, 2, 1, 3).reshape(B * Hv, T + 1, d)
    lgr = lgh.transpose(0, 2, 1).reshape(B * Hv, T + 1)
    br = bh.transpose(0, 2, 1).reshape(B * Hv, T + 1)
    O_ref, S_ref = ref.gdn_prefill_ref(qr, kr, vr, lgr, br,
                                       S0.reshape(B * Hv, d, d))
    np.testing.assert_allclose(o2.reshape(B * Hv, d), O_ref[:, -1],
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(S2.reshape(B * Hv, d, d), S_ref,
                               rtol=1e-3, atol=1e-3)


def test_gdn_prefill_strong_gating():
    q, k, v, log_g, beta, S0 = prefill_inputs(11, 1, 64, 2, 2, 32, 32)
    O, S = ops.gdn_prefill(q, k, v, log_g * 25.0, beta, S0, chunk=16)
    assert bool(jnp.all(jnp.isfinite(O)))
    assert bool(jnp.all(jnp.isfinite(S)))


# ---------------------------------------------------------------- attn_decode

@pytest.mark.parametrize("B,Hq,Hkv,T,d", [
    (2, 8, 2, 512, 64),
    (1, 32, 8, 1024, 128),     # GQA 4:1
    (2, 4, 4, 256, 64),        # MHA
])
def test_attn_decode_vs_ref(B, Hq, Hkv, T, d):
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, Hkv, T, d))
    vc = jax.random.normal(ks[2], (B, Hkv, T, d))
    length = jax.random.randint(ks[3], (B,), T // 4, T + 1)
    o = ops.attn_decode(q, kc, vc, length, block_t=128)
    o_ref = ref.attn_decode_ref(q, kc, vc, length)
    np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)


def test_attn_decode_sliding_window():
    B, Hq, Hkv, T, d = 2, 4, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(17), 4)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, Hkv, T, d))
    vc = jax.random.normal(ks[2], (B, Hkv, T, d))
    length = jnp.array([500, 300], jnp.int32)
    o = ops.attn_decode(q, kc, vc, length, block_t=128, window=128)
    o_ref = ref.attn_decode_ref(q, kc, vc, length, window=128)
    np.testing.assert_allclose(o, o_ref, rtol=2e-4, atol=2e-4)


def test_attn_decode_block_sweep():
    B, Hq, Hkv, T, d = 1, 8, 4, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(19), 3)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, Hkv, T, d))
    vc = jax.random.normal(ks[2], (B, Hkv, T, d))
    length = jnp.array([512], jnp.int32)
    outs = [ops.attn_decode(q, kc, vc, length, block_t=bt)
            for bt in (64, 128, 256, 512)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-4, atol=2e-4)
