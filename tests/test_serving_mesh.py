"""Mesh-sharded serving: slot-axis DP + head-sharded state, router, ring.

Three layers of coverage:

  * **in-process** (single device): topology parsing/padding, up-front
    mesh-shape validation, the staging-buffer ring (depth knob, parity,
    multiple outstanding ahead-of-slot prefills), and the router
    (placement policies, rebalance, drain, metrics aggregation).
  * **subprocess** (8 virtual CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``, the
    ``test_parallel.py`` idiom): bitwise token-stream parity between a
    1-device mesh and an 8-device data-sharded mesh for greedy *and*
    stochastic sampling; numeric parity (float-reduction tolerance) plus
    end-to-end completion for the head-sharded (4, 2) mesh; and buffer
    sharding placement assertions (slot axis on "data", state heads /
    KV context on "model").

The data axis moves *placement* only — per-slot arithmetic is unchanged,
so streams are bitwise identical.  The model axis splits head/context
reductions (psum partial ordering), so it is checked at float tolerance,
like any tensor-parallel serving stack.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ServingTopology
from repro.launch import mesh as mesh_mod
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request, Router


@pytest.fixture(scope="module")
def gdn_model():
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _reqs(n, stochastic=False):
    return [Request(rid=i, prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                    max_new_tokens=4 + i,
                    temperature=0.8 if stochastic and i % 2 else 0.0,
                    top_k=10 if stochastic and i % 2 else 0)
            for i in range(n)]


# ------------------------------------------------------------- topology

def test_topology_parse_and_pad():
    t = ServingTopology.parse("4,2")
    assert t.shape == (4, 2) and t.axes == ("data", "model")
    assert t.devices == 8
    t = ServingTopology.parse("data=2,model=3", staging_depth=3)
    assert (t.data, t.model, t.staging_depth) == (2, 3, 3)
    assert ServingTopology(data=4).pad_slots(5) == 8
    assert ServingTopology(data=4).pad_slots(8) == 8
    assert ServingTopology().pad_slots(3) == 3
    for bad in ("4", "4,2,1", "data=4,oops=2", "0,2", "a,b"):
        with pytest.raises(ValueError):
            ServingTopology.parse(bad)


def test_validate_mesh_shape_up_front():
    """A bad topology must fail with an actionable one-liner before any
    jit sees the mesh (it used to surface deep inside partitioning)."""
    assert mesh_mod.validate_mesh_shape((1, 1), ("data", "model")) == (1, 1)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        mesh_mod.validate_mesh_shape((4, 2), ("data", "model"),
                                     device_count=1)
    with pytest.raises(ValueError, match="positive int"):
        mesh_mod.validate_mesh_shape((0, 2), ("data", "model"))
    with pytest.raises(ValueError, match="axes"):
        mesh_mod.validate_mesh_shape((2, 2, 2), ("data", "model"))
    with pytest.raises(ValueError, match="duplicate"):
        mesh_mod.validate_mesh_shape((2, 2), ("data", "data"),
                                     device_count=4)
    if jax.device_count() < 4:              # single-device test process
        with pytest.raises(ValueError, match="needs 4 devices"):
            mesh_mod.make_serving_mesh(2, 2)


# --------------------------------------------------------- staging ring

def _serve(cfg, params, *, staging_depth, overlap=True, stochastic=False,
           n=6, slots=2):
    eng = DecodeEngine(cfg, params, max_slots=slots, max_len=64,
                       decode_block=4, overlap=overlap, prefill_chunk=8,
                       staging_depth=staging_depth)
    reqs = _reqs(n, stochastic)
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.output) for r in reqs]


def test_staging_ring_parity(gdn_model):
    """Ring depth moves *when* prefills run, never what is computed:
    token streams are bitwise identical across depths (and vs the
    serialized baseline)."""
    cfg, params = gdn_model
    _, base = _serve(cfg, params, staging_depth=1, overlap=False)
    for depth in (1, 2, 3):
        _, out = _serve(cfg, params, staging_depth=depth)
        assert out == base, f"depth={depth} diverged"
    _, st = _serve(cfg, params, staging_depth=2, stochastic=True)
    _, st1 = _serve(cfg, params, staging_depth=1, stochastic=True)
    assert st == st1


def test_staging_ring_multiple_outstanding(gdn_model):
    """Under saturation a depth-2 ring keeps two ahead-of-slot prefills
    in flight (the single-buffer executor could only hold one)."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8,
                       staging_depth=2)
    eng.submit(Request(rid=9, prompt=np.arange(1, 18, dtype=np.int32),
                       max_new_tokens=40))
    eng.step()                                  # slot occupied, decoding
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(1, 18, dtype=np.int32),
                           max_new_tokens=4))
    eng.step()
    # both ring buffers staging (third request still queued), slot busy
    assert len(eng._stagings) == 2
    assert len(eng.queue) == 1
    eng.step()                                  # 17-token plans complete:
    # both staged requests have their first token before any slot frees
    first_two = [r for r in eng._all if r.rid in (0, 1)]
    assert all(len(r.output) == 1 for r in first_two)
    assert not any(r.done for r in eng._all if r.rid == 9)
    eng.run_until_done()
    assert all(r.done for r in eng._all)


def test_staging_depth_validation(gdn_model):
    cfg, params = gdn_model
    with pytest.raises(ValueError, match="staging_depth"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32, staging_depth=0)


def test_metrics_report_topology(gdn_model):
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=32,
                       staging_depth=3)
    m = eng.metrics()
    assert m["staging_depth"] == 3
    assert m["mesh_data"] == 1 and m["mesh_model"] == 1


# --------------------------------------------------------------- router

def _mini_engine(cfg, params, slots=2):
    return DecodeEngine(cfg, params, max_slots=slots, max_len=64,
                        decode_block=2, prefill_chunk=8)


def test_router_round_robin_placement(gdn_model):
    cfg, params = gdn_model
    r = Router([_mini_engine(cfg, params) for _ in range(3)],
               policy="round_robin")
    idxs = [r.submit(q) for q in _reqs(6)]
    assert idxs == [0, 1, 2, 0, 1, 2]
    assert r.placed == [2, 2, 2]


def test_router_least_loaded_placement(gdn_model):
    cfg, params = gdn_model
    engs = [_mini_engine(cfg, params) for _ in range(2)]
    r = Router(engs)                      # least_loaded is the default
    # preload engine 0 with two requests -> next three go 1, 1, 0
    r.engines[0].submit(Request(rid=90, prompt=np.arange(1, 9,
                                                         dtype=np.int32)))
    r.engines[0].submit(Request(rid=91, prompt=np.arange(1, 9,
                                                         dtype=np.int32)))
    idxs = [r.submit(q) for q in _reqs(3)]
    assert idxs == [1, 1, 0]


def test_router_rebalance_on_shard_full(gdn_model):
    """Queued requests migrate from a shard-full engine to an idle one;
    t_submit survives the move so TTFT measures the client's wait."""
    cfg, params = gdn_model
    engs = [_mini_engine(cfg, params, slots=1) for _ in range(2)]
    r = Router(engs, policy="round_robin")
    # jam engine 0: one active (via step) + two queued behind it
    busy = Request(rid=50, prompt=np.arange(1, 9, dtype=np.int32),
                   max_new_tokens=30)
    engs[0].submit(busy)
    engs[0].step()
    q1 = Request(rid=51, prompt=np.arange(1, 9, dtype=np.int32),
                 max_new_tokens=4)
    q2 = Request(rid=52, prompt=np.arange(1, 9, dtype=np.int32),
                 max_new_tokens=4)
    engs[0].submit(q1)
    engs[0].submit(q2)
    t_orig = q2.t_submit
    moved = r.rebalance()
    assert moved >= 1
    assert r.migrated == moved
    # tail request moved to the idle engine, head kept FIFO position
    assert q2 in engs[1].queue or q2 in engs[1]._all
    assert q2.t_submit == t_orig
    assert engs[0].queue and engs[0].queue[0] is q1
    done = r.run_until_done()
    assert {q.rid for q in done} == {50, 51, 52}


def test_router_drain(gdn_model):
    cfg, params = gdn_model
    engs = [_mini_engine(cfg, params) for _ in range(2)]
    r = Router(engs, policy="round_robin")
    for q in _reqs(4):
        r.submit(q)                 # 2 queued on each engine
    moved = r.drain(0)
    assert moved == 2
    assert not engs[0].queue
    assert len(engs[1].queue) == 4
    # new submissions skip the draining engine
    extra = Request(rid=99, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=2)
    assert r.submit(extra) == 1
    r.undrain(0)
    with pytest.raises(RuntimeError, match="draining"):
        rr = Router([_mini_engine(cfg, params)])
        rr.drain(0)


def test_router_metrics_aggregate(gdn_model):
    cfg, params = gdn_model
    engs = [_mini_engine(cfg, params) for _ in range(2)]
    r = Router(engs, policy="round_robin")
    reqs = _reqs(4)
    for q in reqs:
        r.submit(q)
    done = r.run_until_done()
    assert len(done) == 4 and all(q.done for q in reqs)
    m = r.metrics()
    per = m["per_engine"]
    assert m["engines"] == 2 and len(per) == 2
    assert m["requests"] == per[0]["requests"] + per[1]["requests"] == 4
    assert m["tokens"] == sum(p["tokens"] for p in per)
    assert m["ticks"] == sum(p["ticks"] for p in per)
    assert m["decoded_tokens"] == sum(p["decoded_tokens"] for p in per)
    assert m["placed"] == [2, 2]
    assert m["mean_ttft_s"] > 0.0
    # single-engine router == the engine itself (same streams)
    single = _mini_engine(cfg, params)
    rs = Router([single])
    reqs2 = _reqs(4)
    for q in reqs2:
        rs.submit(q)
    rs.run_until_done()
    by_rid = {q.rid: q.output for q in reqs}
    assert all(by_rid[q.rid] == q.output for q in reqs2)


def test_router_migration_rejection_keeps_request(gdn_model):
    """A heterogeneous taker (smaller max_len) rejecting a migrated
    request must not drop it: it goes back on the donor's queue."""
    cfg, params = gdn_model
    donor = _mini_engine(cfg, params, slots=1)
    small = DecodeEngine(cfg, params, max_slots=2, max_len=8,
                         decode_block=2, prefill_chunk=8)
    r = Router([donor, small], policy="round_robin")
    busy = Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32),
                   max_new_tokens=20)
    donor.submit(busy)
    donor.step()                            # slot busy
    long = Request(rid=2, prompt=np.arange(1, 15, dtype=np.int32),
                   max_new_tokens=2)        # 14 tokens > small's max_len
    donor.submit(long)
    with pytest.warns(RuntimeWarning, match="rejected migrated"):
        moved = r.rebalance()
    assert moved == 0
    assert long in donor.queue and long in donor._all
    done = r.run_until_done()
    assert {q.rid for q in done} == {1, 2}


def test_withdraw_keeps_metrics_watermark(gdn_model):
    """Withdrawing a pre-reset request must not shift post-reset requests
    out of the metrics window."""
    cfg, params = gdn_model
    eng = _mini_engine(cfg, params)
    a = Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32),
                max_new_tokens=2)
    eng.submit(a)
    eng.reset_metrics()                     # watermark past the queued a
    b = Request(rid=2, prompt=np.arange(1, 9, dtype=np.int32),
                max_new_tokens=2)
    eng.submit(b)
    assert eng.withdraw(oldest=True) is a   # a leaves; watermark follows
    eng.run_until_done()
    m = eng.metrics()
    assert m["requests"] == 1 and b.done


def test_router_validation(gdn_model):
    cfg, params = gdn_model
    with pytest.raises(ValueError, match="at least one"):
        Router([])
    with pytest.raises(ValueError, match="policy"):
        Router([_mini_engine(cfg, params)], policy="random")


# ----------------------------------------- multi-device (subprocess, 8x)

SUBPROCESS_TEST = textwrap.dedent("""
    import os, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.models import lm
    from repro.parallel import sharding as rules
    from repro.serving.engine import DecodeEngine, Request

    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)

    def serve(mesh, stochastic, slots=8):
        eng = DecodeEngine(cfg, params, max_slots=slots, max_len=64,
                           decode_block=4, prefill_chunk=8, mesh=mesh)
        reqs = [Request(rid=i,
                        prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                        max_new_tokens=4 + i,
                        temperature=0.8 if stochastic and i % 2 else 0.0,
                        top_k=10 if stochastic and i % 2 else 0)
                for i in range(6)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        return eng, [list(r.output) for r in reqs]

    # --- 1. bitwise parity: 1-device mesh == 8-device data-sharded mesh
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    for stochastic in (False, True):
        _, base = serve(mesh1, stochastic)
        eng8, out8 = serve(mesh8, stochastic)
        assert out8 == base, (
            f"slot-axis DP must be bitwise (stochastic={stochastic}):"
            f" {out8} vs {base}")

    # --- 2. buffer placement: slot axis on data, state heads / KV
    #        context on model
    mesh42 = jax.make_mesh((4, 2), ("data", "model"))
    eng42, out42 = serve(mesh42, False)

    def ax(entry):          # normalize a PartitionSpec entry to a tuple
        if entry is None:
            return ()
        return entry if isinstance(entry, tuple) else (entry,)

    flat, _ = jax.tree_util.tree_flatten_with_path(eng42.executor.caches)
    spec_of = {rules.path_str(p): l.sharding.spec for p, l in flat}
    s_specs = [s for p, s in spec_of.items() if p.endswith("/S")]
    kv_specs = [s for p, s in spec_of.items()
                if p.endswith("/k") or p.endswith("/v")]
    assert s_specs and all(ax(s[1]) == ("data",) and ax(s[2]) == ("model",)
                           for s in s_specs), s_specs
    assert kv_specs and all(ax(s[1]) == ("data",) and ax(s[3]) == ("model",)
                            for s in kv_specs), kv_specs
    assert ax(eng42.executor.tokens.sharding.spec[0]) == ("data",)
    assert ax(eng42.executor.sampler["key"].sharding.spec[0]) == ("data",)
    # staging ring: replicated on the slot axis, same model placement
    st_flat, _ = jax.tree_util.tree_flatten_with_path(
        eng42.executor.staging[0])
    st_specs = [l.sharding.spec for _, l in st_flat]
    assert all(len(s) < 2 or ax(s[1]) == () for s in st_specs)
    assert any(any(ax(e) == ("model",) for e in s) for s in st_specs)
    assert eng42.metrics()["mesh_data"] == 4
    assert eng42.metrics()["mesh_model"] == 2

    # --- 3. head-sharded numerics: same math to float-reduction order
    #        (psum partials), like any TP stack — checked at tolerance
    S = 8
    caches = lm.init_caches(cfg, S, 64)
    tok = jnp.arange(1, S + 1, dtype=jnp.int32)
    logits_ref, _ = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c))(params, tok, caches)
    shapes = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), caches)
    cache_sh = rules.make_shardings(
        mesh42, rules.cache_specs(cfg, mesh42, shapes, S))
    p_sh = rules.make_shardings(
        mesh42, rules.params_specs(cfg, params, False, mesh42))
    tok_sh = NamedSharding(mesh42, P("data"))
    logits_s, _ = jax.jit(
        lambda p, t, c: lm.decode_step(p, cfg, t, c),
        in_shardings=(p_sh, tok_sh, cache_sh))(
            jax.device_put(params, p_sh), jax.device_put(tok, tok_sh),
            jax.device_put(caches, cache_sh))
    np.testing.assert_allclose(np.asarray(logits_ref),
                               np.asarray(logits_s), rtol=2e-4, atol=2e-4)

    # --- 4. non-dividing slot count: loud warning, still completes (the
    #        dropped data annotation may be re-placed on a state dim by
    #        fit_spec, so bitwise parity is only promised for padded
    #        counts — ServingTopology.pad_slots)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        _, out_odd = serve(mesh8, False, slots=6)
    assert any("pad_slots" in str(x.message) for x in w)
    assert all(len(o) == 4 + i for i, o in enumerate(out_odd))

    print("SUBPROCESS_MESH_OK")
""")


def test_sharded_serving_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1800)
    assert "SUBPROCESS_MESH_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


# ------------------------------------ state paging under a mesh (subproc)

SUBPROCESS_PAGING_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serving.engine import DecodeEngine, Request

    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)

    def reqs():
        # rid 0 — the paused one — samples stochastically: the swapped
        # image must round-trip the PRNG key mid-stream
        return [Request(rid=i,
                        prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                        max_new_tokens=6 + i,
                        temperature=0.8 if i % 2 == 0 else 0.0,
                        top_k=10 if i % 2 == 0 else 0,
                        top_p=0.9 if i % 2 == 0 else 1.0)
                for i in range(6)]

    def serve(mesh, paged):
        eng = DecodeEngine(cfg, params, max_slots=4, max_len=64,
                           decode_block=4, prefill_chunk=8, mesh=mesh)
        rr = reqs()
        for q in rr:
            eng.submit(q)
        if paged:
            for _ in range(50):
                eng.step()
                if rr[0].state == "active" and len(rr[0].output) >= 2:
                    break
            assert rr[0].state == "active", rr[0].state
            eng.pause(0)
            sw = eng.swapped[0].state
            # gathered under a mesh, the host image is plain replicated
            # numpy — topology-free, restorable on any same-cfg engine
            assert all(isinstance(x, np.ndarray)
                       for x in jax.tree.leaves(sw.caches))
            eng.step()
            eng.resume(0)
        eng.run_until_done()
        assert all(q.done for q in rr)
        return eng, [list(q.output) for q in rr]

    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    mesh4 = jax.make_mesh((4, 1), ("data", "model"),
                          devices=jax.devices()[:4])

    # --- 1. bitwise parity: pause/resume on a 1-device mesh AND a
    #        4-device data-sharded mesh both reproduce the uninterrupted
    #        1-device streams exactly
    _, base = serve(mesh1, False)
    for mesh in (mesh1, mesh4):
        _, out = serve(mesh, True)
        assert out == base, (out, base)

    # --- 2. placement restored leaf-by-leaf: after a swap-out/swap-in
    #        round trip every slot buffer carries the same NamedSharding
    #        spec as an engine that never swapped
    eng_ref, _ = serve(mesh4, False)
    eng_sw, _ = serve(mesh4, True)
    ref = [l.sharding.spec
           for l in jax.tree.leaves(eng_ref.executor.caches)]
    got = [l.sharding.spec
           for l in jax.tree.leaves(eng_sw.executor.caches)]
    assert got == ref, list(zip(got, ref))[:4]
    assert (eng_sw.executor.tokens.sharding.spec
            == eng_ref.executor.tokens.sharding.spec)
    for k in eng_sw.executor.sampler:
        assert (eng_sw.executor.sampler[k].sharding.spec
                == eng_ref.executor.sampler[k].sharding.spec), k
    m = eng_sw.metrics()
    assert m["swap_outs"] >= 1 and m["swap_ins"] >= 1
    assert m["swap_bytes"] >= 2 * eng_sw.executor.swap_bytes_per_slot
    print("SUBPROCESS_PAGING_OK")
""")


def test_sharded_swap_subprocess():
    """Swap/resume on a data-sharded mesh: bitwise parity with the
    1-device run, and sharding placement restored leaf-by-leaf."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_PAGING_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1800)
    assert "SUBPROCESS_PAGING_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


# ------------------------------- async state paging under a mesh (subproc)

SUBPROCESS_ASYNC_PAGING_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serving.engine import DecodeEngine, Request

    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)

    def reqs():
        # rid 0 — the paused one — samples stochastically: the swapped
        # image must round-trip the PRNG key mid-stream
        return [Request(rid=i,
                        prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                        max_new_tokens=6 + i,
                        temperature=0.8 if i % 2 == 0 else 0.0,
                        top_k=10 if i % 2 == 0 else 0,
                        top_p=0.9 if i % 2 == 0 else 1.0)
                for i in range(6)]

    def serve(mesh, paged, async_paging=False):
        eng = DecodeEngine(cfg, params, max_slots=4, max_len=64,
                           decode_block=4, prefill_chunk=8, mesh=mesh,
                           async_paging=async_paging)
        rr = reqs()
        for q in rr:
            eng.submit(q)
        if paged:
            for _ in range(50):
                eng.step()
                if rr[0].state == "active" and len(rr[0].output) >= 2:
                    break
            assert rr[0].state == "active", rr[0].state
            eng.pause(0)
            if async_paging:
                # slot freed at dispatch; the D2H drain is in flight
                assert eng.swapped[0].pending is not None
            eng.step()
            eng.resume(0)
        eng.run_until_done()
        assert all(q.done for q in rr)
        return eng, [list(q.output) for q in rr]

    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    mesh4 = jax.make_mesh((4, 1), ("data", "model"),
                          devices=jax.devices()[:4])

    # --- 1. bitwise parity: ASYNC pause/resume on a 1-device mesh and a
    #        4-device data-sharded mesh both reproduce the synchronous
    #        1-device paged run — which itself reproduces the
    #        uninterrupted base streams exactly
    _, base = serve(mesh1, False)
    _, sync1 = serve(mesh1, True, async_paging=False)
    assert sync1 == base, (sync1, base)
    for mesh in (mesh1, mesh4):
        eng, out = serve(mesh, True, async_paging=True)
        assert out == base, (out, base)
        m = eng.metrics()
        assert m["async_paging"] == 1 and m["swap_outs"] >= 1

    # --- 2. a prestaged (prefetched) restore image carries the
    #        canonical staging placements leaf-by-leaf — the
    #        grant-boundary scatter must consume it with zero relayout
    eng = DecodeEngine(cfg, params, max_slots=4, max_len=64,
                       decode_block=4, prefill_chunk=8, mesh=mesh4,
                       async_paging=True)
    rr = reqs()
    for q in rr:
        eng.submit(q)
    for _ in range(50):
        eng.step()
        if rr[0].state == "active" and len(rr[0].output) >= 2:
            break
    assert rr[0].state == "active", rr[0].state
    eng.pause(0)
    eng.flush_swaps()            # harvest the drain so prestage can run
    eng.resume(0)
    eng._prefetch_resume()       # slot is free -> grant is predictable
    rec = eng.swapped[0]
    assert rec.prefetch is not None, "prefetch did not stage"
    st, row, tok = rec.prefetch
    x = eng.executor
    got = [l.sharding for l in jax.tree.leaves(st)]
    want = jax.tree.leaves(x._sh_staging)
    assert len(got) == len(want) and got == want, \
        list(zip(got, want))[:4]
    row_got = [l.sharding for l in jax.tree.leaves(row)]
    row_want = jax.tree.leaves(x._sh_row)
    assert row_got == row_want, list(zip(row_got, row_want))[:4]
    assert tok.sharding == x._sh_rep, tok.sharding
    assert eng.metrics()["swap_prefetches"] >= 1
    eng.run_until_done()
    assert [list(q.output) for q in rr] == base
    assert eng.metrics()["swap_prefetch_hits"] >= 1
    print("SUBPROCESS_ASYNC_PAGING_OK")
""")


def test_sharded_async_swap_subprocess():
    """Async pause/resume on a data-sharded mesh: streams bitwise equal
    to the 1-device synchronous paged run, and a prefetched restore
    image's leaf shardings match the executor's canonical staging /
    sampler-row / replicated placements."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c",
                        SUBPROCESS_ASYNC_PAGING_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1800)
    assert "SUBPROCESS_ASYNC_PAGING_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


# ------------------------------ speculative decode under a mesh (subproc)

SUBPROCESS_SPEC_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serving.engine import DecodeEngine, Request

    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)

    def serve(mesh, speculative, stochastic):
        eng = DecodeEngine(cfg, params, max_slots=4, max_len=64,
                           decode_block=4, prefill_chunk=8, mesh=mesh,
                           speculative=speculative, k_draft=4)
        rr = [Request(rid=i,
                      prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                      max_new_tokens=6 + i,
                      temperature=0.8 if stochastic and i % 2 == 0 else 0.0,
                      top_k=10 if stochastic and i % 2 == 0 else 0,
                      top_p=0.9 if stochastic and i % 2 == 0 else 1.0)
              for i in range(6)]
        for q in rr:
            eng.submit(q)
        eng.run_until_done()
        assert all(q.done for q in rr)
        return eng, [list(q.output) for q in rr]

    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    mesh4 = jax.make_mesh((4, 1), ("data", "model"),
                          devices=jax.devices()[:4])

    # --- 1. bitwise parity: data-sharded speculative streams == the
    #        1-device non-speculative streams, greedy AND stochastic
    for stochastic in (False, True):
        _, base = serve(mesh1, False, stochastic)
        for mesh in (mesh1, mesh4):
            eng, out = serve(mesh, True, stochastic)
            assert out == base, (
                f"speculative mesh decode must be bitwise "
                f"(stochastic={stochastic}): {out} vs {base}")
            assert eng.metrics()["acceptance_rate"] > 0.5

    # --- 2. checkpoint/draft buffers carry the same placements as the
    #        slot caches (checkpoint_specs == cache_specs rules), so the
    #        commit/rollback select and the cache<->ckpt ping-pong stay
    #        communication-free
    eng, _ = serve(mesh4, True, False)
    x = eng.executor
    cache_specs = [l.sharding.spec for l in jax.tree.leaves(x.caches)]
    ckpt_specs = [l.sharding.spec for l in jax.tree.leaves(x.ckpt)]
    assert ckpt_specs == cache_specs, list(zip(ckpt_specs,
                                               cache_specs))[:4]
    d_specs = [l.sharding.spec for l in jax.tree.leaves(x.dcaches)]
    dk_specs = [l.sharding.spec for l in jax.tree.leaves(x.dckpt)]
    assert dk_specs == d_specs
    slot_ax = [s[1] for s in d_specs if len(s) > 1]
    assert slot_ax and all(a in ("data", ("data",)) for a in slot_ax), \\
        slot_ax
    print("SUBPROCESS_SPEC_OK")
""")


def test_sharded_spec_decode_subprocess():
    """Speculative decode on a data-sharded mesh: streams bitwise equal
    to the 1-device non-speculative run, and the rollback checkpoint /
    draft buffers share the slot caches' placements."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_SPEC_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1800)
    assert "SUBPROCESS_SPEC_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]
