"""State paging (slot oversubscription): swap/resume must never change
a token.

The paper's capacity argument is that linear-attention state is a
*fixed-size* block — the serving analog is that a request's whole device
residency (recurrent state + rolling KV window + sampler row + last
token, all shapes from ``cache_spec``) gathers into one host record and
restores through the existing slot scatter.  Gather is a
``dynamic_slice`` and restore a ``dynamic_update_slice`` of the same
dtype, the PRNG key is (seed, rid)-folded and round-trips mid-stream,
and decode is Markov in (state, last token, sampler row) — so a
preempted-and-resumed stream is bitwise the uninterrupted one.  Pinned
here, per mixer family:

  * pause mid-decode + resume == uninterrupted, greedy AND stochastic,
    for all five mixer kinds + gdn_naive (co-resident streams also
    unchanged — a swap never perturbs its neighbors);
  * swap at the prefill→decode admit boundary (staged-ready, never held
    a slot) on both the batched and per-prompt staging paths, plus the
    deferred mid-prefill pause (``pause_pending``) and its cancellation;
  * swap of a request whose rolling-window attn cache has wrapped;
  * preempt/priority-pressure/idle-lease policies, grant fairness, and
    repeated swap cycles all converge to the dedicated-slot streams;
  * swap-aware metrics: TTFT and tokens/s exclude swapped-out wall
    time; ``reset_metrics`` marks the window by completion so a request
    parked across a reset still counts (the old submit-index watermark
    lost it);
  * lifecycle errors: rid-keyed bookkeeping rejects duplicates, paging
    verbs reject wrong-state targets, ``max_live_requests`` caps
    admission including swapped sessions;
  * async paging (``async_paging=True``): swap-outs drain D2H in the
    background through a gather-buffer ring and resume grants prestage
    their H2D put — gather outputs snapshot values at dispatch, so
    streams are bitwise the synchronous ones for every mixer kind; the
    ring ledger never reuses a draining buffer pre-harvest, a cancelled
    resume drops its prefetch, ``swap_s`` splits into dispatch vs stall
    and parked time spans gather dispatch -> restore scatter;
  * spill-to-disk: beyond the ``host_swap_bytes`` watermark the coldest
    dormant image spills to a wire-encoded ``swap-<rid>.state``
    under ``swap_spool_dir`` and
    reloads transparently (and bitwise) on resume.
"""
import os
import time
from collections import deque

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import scheduler as sched
from repro.serving.engine import DecodeEngine, Request, Router
from repro.serving.executor import SwappedState

ARCHS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}
KINDS = list(ARCHS) + ["gdn_naive"]

_MODELS = {}


def _model(kind):
    if kind not in _MODELS:
        cfg = configs.get_arch(ARCHS.get(kind, ARCHS["gdn"])).reduced()
        if os.environ.get("REPRO_PALLAS_SERVING") == "1":
            cfg = cfg.replace(use_pallas_serving=True)
        if kind == "gdn_naive":
            cfg = cfg.replace(pattern=tuple(
                "gdn_naive" if k == "gdn" else k for k in cfg.pattern))
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        _MODELS[kind] = (cfg, params)
    return _MODELS[kind]


def _engine(kind, **kw):
    cfg, params = _model(kind)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_block", 2)
    kw.setdefault("prefill_chunk", 8)
    return DecodeEngine(cfg, params, **kw)


def _reqs(n, stochastic, max_new=8):
    # rid 0 — the request every test pauses — is stochastic when asked:
    # the PRNG-key round-trip is the fragile part of the swap
    return [Request(rid=i, prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                    max_new_tokens=max_new + i,
                    temperature=0.8 if stochastic and i % 2 == 0 else 0.0,
                    top_k=10 if stochastic and i % 2 == 0 else 0,
                    top_p=0.9 if stochastic and i % 2 == 0 else 1.0)
            for i in range(n)]


def _drain(eng, reqs, max_ticks=500):
    """Run to completion, reconnecting any parked session (idle-policy
    runs park sessions dormant; every resume must still finish them)."""
    for _ in range(max_ticks):
        if all(r.done for r in reqs):
            return
        for rid in list(eng.swapped):
            if rid not in eng.resume_q:
                eng.resume(rid)
        eng.step()
    raise AssertionError("drain did not converge")


def _streams(reqs):
    return [list(r.output) for r in reqs]


def _ref_streams(kind, stochastic, **kw):
    eng = _engine(kind, **kw)
    reqs = _reqs(3, stochastic)
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return _streams(reqs)


def _step_until(eng, pred, max_ticks=100):
    for _ in range(max_ticks):
        eng.step()
        if pred():
            return
    raise AssertionError("condition not reached")


# ----------------------------------------------- mid-decode swap parity

@pytest.mark.parametrize("stochastic", [False, True],
                         ids=["greedy", "stochastic"])
@pytest.mark.parametrize("kind", KINDS)
def test_pause_resume_mid_decode_bitwise(kind, stochastic):
    """A stream preempted mid-decode and resumed is bitwise the
    uninterrupted one — and so are the co-resident streams that kept
    decoding past the stale post-swap slot rows."""
    ref = _ref_streams(kind, stochastic)
    eng = _engine(kind)
    reqs = _reqs(3, stochastic)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (reqs[0].state == sched.ACTIVE
                              and len(reqs[0].output) >= 2))
    eng.pause(0)
    assert reqs[0].state == sched.SWAPPED and 0 in eng.swapped
    assert isinstance(eng.swapped[0].state, SwappedState)
    eng.step()                      # neighbors decode over the freed slot
    eng.step()
    eng.resume(0)
    assert reqs[0].state == sched.RESUMING and 0 in eng.resume_q
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref
    m = eng.metrics()
    assert m["swap_outs"] >= 1 and m["swap_ins"] >= 1
    assert m["swap_bytes"] >= 2 * eng.executor.swap_bytes_per_slot


def test_swapped_image_matches_spec_budget():
    """The host image is plain numpy in the staging layout and its byte
    count is exactly the spec-derived per-slot swap budget the launcher
    and benchmarks report."""
    eng = _engine("gdn")
    req = _reqs(1, False)[0]
    eng.submit(req)
    _step_until(eng, lambda: req.state == sched.ACTIVE)
    eng.pause(0)
    sw = eng.swapped[0].state
    leaves = jax.tree.leaves(sw.caches)
    assert leaves and all(isinstance(x, np.ndarray) for x in leaves)
    assert all(x.shape[1] == 1 for x in leaves)     # staging layout
    assert isinstance(sw.token, np.ndarray) and sw.token.shape == (1,)
    assert set(sw.sampler) == set(eng.executor.sampler)
    assert sw.nbytes == eng.executor.swap_bytes_per_slot
    eng.resume(0)
    eng.run_until_done()
    assert req.done


# ------------------------------------------------- admit-boundary swaps

def _boundary_reqs():
    return [Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=24),
            Request(rid=1, prompt=np.arange(1, 14, dtype=np.int32),
                    max_new_tokens=6, temperature=0.8, top_k=10,
                    top_p=0.9)]


@pytest.mark.parametrize("async_paging", [False, True],
                         ids=["sync", "async"])
@pytest.mark.parametrize("batching", [None, False],
                         ids=["batched", "per_prompt"])
def test_swap_at_admit_boundary(batching, async_paging):
    """Pause of a staged-ready request (first token drawn, no slot yet)
    gathers the staging row/buffer instead of a slot column; the resumed
    stream is bitwise the uninterrupted one on both staging paths —
    synchronous and async (background-drained) alike."""
    eng = _engine("gdn", max_slots=1, prefill_batching=batching)
    rr = _boundary_reqs()
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    ref = _streams(rr)

    eng = _engine("gdn", max_slots=1, prefill_batching=batching,
                  async_paging=async_paging)
    rr = _boundary_reqs()
    eng.submit(rr[0])
    eng.step()                                  # the only slot is busy
    eng.submit(rr[1])
    _step_until(eng, lambda: rr[1].state == sched.READY)
    assert len(rr[1].output) == 1 and not eng.free
    eng.pause(1)
    assert rr[1].state == sched.SWAPPED and 1 in eng.swapped
    eng.step()
    eng.resume(1)
    eng.run_until_done()
    assert all(r.done for r in rr)
    assert _streams(rr) == ref
    assert eng.metrics()["swap_outs"] == 1


@pytest.mark.parametrize("batching", [None, False],
                         ids=["batched", "per_prompt"])
def test_pause_mid_prefill_defers_to_admit(batching):
    """A pause that lands mid-prefill has no admit-advanced sampler row
    to gather: it is marked pending, the chunk plan finishes, and the
    swap happens at the admit boundary.  Resuming before that boundary
    simply cancels the pause (zero swaps)."""
    long_prompt = np.arange(1, 61, dtype=np.int32)      # 7 chunks + tail

    def pair():
        return [Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=30),
                Request(rid=1, prompt=long_prompt, max_new_tokens=5,
                        temperature=0.8, top_k=10, top_p=0.9)]

    eng = _engine("gdn", max_slots=1, prefill_batching=batching)
    rr = pair()
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    ref = _streams(rr)

    for cancel in (False, True):
        eng = _engine("gdn", max_slots=1, prefill_batching=batching)
        rr = pair()
        eng.submit(rr[0])
        eng.step()                              # slot busy before staging
        eng.submit(rr[1])
        _step_until(eng, lambda: rr[1].state == sched.STAGING)
        assert eng.pause(1) is rr[1]
        assert 1 not in eng.swapped             # pending, not yet gathered
        assert rr[1].state == sched.STAGING
        if cancel:
            assert eng.resume(1) is rr[1]       # cancel before the boundary
        else:
            _step_until(eng, lambda: rr[1].state == sched.SWAPPED)
            assert not rr[1].done and len(rr[1].output) == 1
            eng.resume(1)
        eng.run_until_done()
        assert all(r.done for r in rr)
        assert _streams(rr) == ref
        assert eng.metrics()["swap_outs"] == (0 if cancel else 1)


# -------------------------------------------------- rolling-window wrap

@pytest.mark.parametrize("stochastic", [False, True],
                         ids=["greedy", "stochastic"])
def test_swap_inside_window_wrap(stochastic):
    """Swap of a request whose rolling-window attn cache has wrapped:
    the gathered image includes the wrapped KV ring and its position
    meta, so restore lands mid-wrap bitwise."""
    cfg, _ = _model("swa")
    W = cfg.window
    assert W and W < 64, "reduced swa config must have a sub-max_len window"
    prompt = np.arange(1, W + 9, dtype=np.int32)        # wraps in prefill

    def pair():
        return [Request(rid=i, prompt=prompt, max_new_tokens=10 + 4 * i,
                        temperature=0.8 if stochastic and i == 0 else 0.0,
                        top_k=10 if stochastic and i == 0 else 0,
                        top_p=0.9 if stochastic and i == 0 else 1.0)
                for i in range(2)]

    eng = _engine("swa")
    rr = pair()
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    ref = _streams(rr)

    eng = _engine("swa")
    rr = pair()
    for r in rr:
        eng.submit(r)
    # pause only once decode itself has advanced past the wrap point
    _step_until(eng, lambda: (rr[0].state == sched.ACTIVE
                              and len(rr[0].output) >= 4))
    eng.pause(0)
    eng.step()
    eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in rr)
    assert _streams(rr) == ref


# --------------------------------------------- preemption and policies

def test_preempt_explicit_and_policy_victim():
    """``preempt()`` evicts the lowest-priority active request (ties:
    most recently activated) with automatic resume; the evicted stream
    still finishes bitwise intact."""
    ref = _ref_streams("gdn", False)
    eng = _engine("gdn")
    reqs = _reqs(3, False)
    reqs[0].priority = 1                        # rid 1 is the policy victim
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: len(eng.active) == 2)
    victim = eng.preempt()
    assert victim is reqs[1]
    assert victim.state == sched.RESUMING and 1 in eng.resume_q
    eng.run_until_done()                        # auto-resume drains it
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref

    eng = _engine("gdn")
    assert eng.preempt() is None                # nothing resident
    reqs = _reqs(2, False)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: len(eng.active) == 2)
    assert eng.preempt(rid=1) is reqs[1]        # explicit victim
    with pytest.raises(KeyError):
        eng.preempt(rid=1)                      # no longer active
    eng.run_until_done()
    assert all(r.done for r in reqs)


def test_pressure_policy_evicts_strictly_lower_priority():
    """pressure: a strictly higher-priority waiter evicts the lowest-
    priority active request; equal priorities never displace each other
    (anti-thrash), and both streams finish bitwise intact."""
    def pair():
        return [Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=20),
                Request(rid=1, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=4, priority=5)]

    eng = _engine("gdn", max_slots=1)
    rr = pair()
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    ref = _streams(rr)

    eng = _engine("gdn", max_slots=1, swap_policy="pressure")
    rr = pair()
    eng.submit(rr[0])
    eng.step()
    assert rr[0].state == sched.ACTIVE
    eng.submit(rr[1])                           # strictly outranks rid 0
    _step_until(eng, lambda: rr[1].state == sched.ACTIVE)
    assert rr[0].state in (sched.RESUMING, sched.ACTIVE)
    eng.run_until_done()
    assert all(r.done for r in rr)
    assert rr[1].t_done <= rr[0].t_done         # priority jumped the line
    assert _streams(rr) == ref
    assert eng.metrics()["swap_outs"] >= 1

    eng = _engine("gdn", max_slots=1, swap_policy="pressure")
    rr = _reqs(2, False, max_new=4)             # equal priority
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in rr)
    assert eng.metrics()["swap_outs"] == 0      # never displaced


def test_idle_policy_lease_and_touch():
    """idle: an active request whose activity lease expires is swapped
    out dormant; ``touch`` renews the lease.  Repeated park/reconnect
    cycles still converge to the dedicated-slot streams."""
    ref = _ref_streams("gdn", False)
    # warm up with an effectively-infinite lease: the first ticks pay
    # multi-second jit compiles, which would blow any realistic lease
    # mid-ramp and park everything before the scenario even starts
    eng = _engine("gdn", swap_policy="idle", idle_swap_ms=1e7)
    warm = _reqs(2, False, max_new=4)
    for r in warm:
        eng.submit(r)
    _step_until(eng, lambda: warm[0].state == sched.ACTIVE)
    eng.pause(warm[0].rid)                      # compile gather + swap-in
    eng.resume(warm[0].rid)
    eng.run_until_done()
    eng.idle_swap_ms = 50.0                     # now the lease is real
    reqs = _reqs(3, False)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: len(eng.active) == 2)
    live = sorted(r.rid for r in eng.active.values())
    time.sleep(0.07)                            # both leases go stale
    eng.touch(live[0])                          # ... but one is renewed
    eng.step()
    assert live[0] in {r.rid for r in eng.active.values()}
    parked = [r for r in reqs if r.rid == live[1]][0]
    assert parked.state == sched.SWAPPED and live[1] in eng.swapped
    assert live[1] not in eng.resume_q          # dormant, not resuming
    _drain(eng, reqs)                           # reconnect loop
    assert _streams(reqs) == ref


def test_grant_alternation_no_starvation():
    """When resumed sessions and staged-ready fresh admits both wait for
    slots, grants strictly alternate — neither class starves — and every
    stream matches its dedicated-slot reference."""
    def mk(n):
        return [Request(rid=i, prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=6) for i in range(n)]

    eng = _engine("gdn", max_slots=6, staging_depth=2)
    rr = mk(6)
    for r in rr:
        eng.submit(r)
    eng.run_until_done()
    ref = _streams(rr)

    eng = _engine("gdn", max_slots=2, staging_depth=2)
    rr = mk(6)
    for r in rr[:2]:
        eng.submit(r)
    _step_until(eng, lambda: len(eng.active) == 2)
    eng.preempt()
    eng.preempt()                               # resume_q holds both
    assert len(eng.resume_q) == 2 and not eng.active
    for r in rr[2:]:
        eng.submit(r)                           # fresh admits contend
    eng.run_until_done()
    assert all(r.done for r in rr)
    assert _streams(rr) == ref
    m = eng.metrics()
    assert m["swap_outs"] == 2 and m["swap_ins"] == 2


def test_run_until_done_ignores_dormant():
    """Dormant swapped-out sessions are not pending work: the loop
    returns with them parked, and a later resume finishes them."""
    eng = _engine("gdn")
    reqs = _reqs(2, False, max_new=4)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: reqs[0].state == sched.ACTIVE)
    eng.pause(0)
    eng.run_until_done()
    assert reqs[1].done and not reqs[0].done
    assert reqs[0].state == sched.SWAPPED
    assert eng.load == 0                        # dormant ≠ owed work
    eng.resume(0)
    assert eng.load == 1
    eng.run_until_done()
    assert reqs[0].done


# ------------------------------------------------------- router paging

def test_router_pause_resume_and_swap_migration():
    """The router finds a rid's owning engine for pause/resume/touch,
    and swap-aware rebalance migrates a resume claim from a slot-full
    engine to a compatible idle one — restored there bitwise."""
    cfg, params = _model("gdn")

    def mk(rid, long=False):
        return Request(rid=rid, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=24 if long else 8,
                       temperature=0.8, top_k=10, top_p=0.9)

    ref_eng = _engine("gdn", max_slots=1)
    ref_req = mk(0)
    ref_eng.submit(ref_req)
    ref_eng.run_until_done()

    engs = [DecodeEngine(cfg, params, max_slots=1, max_len=64,
                         decode_block=2, prefill_chunk=8)
            for _ in range(2)]
    router = Router(engs, policy="round_robin")
    a = mk(0)
    assert router.submit(a) == 0
    engs[0].step()
    assert a.state == sched.ACTIVE
    router.pause(0)                             # via the router front door
    assert a.state == sched.SWAPPED and 0 in engs[0].swapped
    hog = mk(10, long=True)
    engs[0].submit(hog)
    engs[0].step()                              # hog takes e0's only slot
    router.resume(0)                            # e0 slot-full -> donor
    assert list(engs[0].resume_q) == [0]
    router.step()                               # rebalance_swapped moves it
    assert 0 in engs[1].swapped or any(
        r.rid == 0 and r.state == sched.ACTIVE for r in engs[1]._all)
    router.touch(0)                             # owner lookup post-move
    done = router.run_until_done()
    assert {r.rid for r in done} == {0, 10}
    assert any(r is a for r in engs[1]._all)    # finished on the taker
    assert list(a.output) == list(ref_req.output)
    m = router.metrics()
    assert m["swap_outs"] >= 1 and m["swap_ins"] >= 1
    assert m["migrated"] >= 1


# ------------------------------------------------- swap-aware metrics

def test_ttft_excludes_pre_first_swapped_time():
    """A request paused out of the queue (client left before prefill)
    must not book its parked wall time as TTFT."""
    eng = _engine("gdn")
    req = _reqs(1, False, max_new=4)[0]
    eng.submit(req)
    eng.pause(0)                                # straight from the queue
    assert eng.swapped[0].state is None         # nothing was resident
    time.sleep(0.05)
    eng.resume(0)
    assert req.state == sched.QUEUED            # re-queued, re-prefills
    eng.run_until_done()
    assert req.done
    assert req.swapped_s >= 0.05
    wall_ttft = req.t_first - req.t_submit
    assert wall_ttft >= 0.05
    assert req.ttft_s < wall_ttft - 0.04        # parked time excluded


def test_throughput_excludes_mid_decode_swapped_time():
    """tokens/s divides by active latency: a request parked mid-decode
    for 50 ms did not decode slowly for 50 ms.  TTFT already happened,
    so it is untouched by the swap."""
    eng = _engine("gdn")
    req = _reqs(1, False, max_new=8)[0]
    eng.submit(req)
    _step_until(eng, lambda: (req.state == sched.ACTIVE
                              and len(req.output) >= 2))
    ttft_before = req.ttft_s
    eng.pause(0)
    time.sleep(0.05)
    eng.resume(0)
    eng.run_until_done()
    assert req.done
    assert req.ttft_s == ttft_before
    assert req.swapped_s >= 0.05
    assert req.active_latency_s <= req.latency_s - 0.04
    assert req.tokens_per_s == pytest.approx(
        len(req.output) / req.active_latency_s)
    m = eng.metrics()
    assert m["mean_tokens_per_s"] == pytest.approx(req.tokens_per_s)


def test_reset_metrics_completion_marked_window():
    """The metrics window is marked by completion, not submit order: a
    request parked across ``reset_metrics`` that finishes after it still
    counts (the old list-index watermark dropped it)."""
    eng = _engine("gdn")
    a, b = _reqs(2, False, max_new=3)[:2]
    eng.submit(a)
    eng.run_until_done()
    assert a.done
    eng.submit(b)
    eng.pause(1)                                # parked across the reset
    eng.reset_metrics()
    assert eng.metrics()["requests"] == 0       # a is pre-window
    eng.resume(1)
    eng.run_until_done()
    assert b.done
    m = eng.metrics()
    assert m["requests"] == 1                   # b survived the reset
    assert m["tokens"] == len(b.output)
    assert m["swap_ins"] == 0                   # queue-pause: no image


# --------------------------------------------------- lifecycle errors

def test_lifecycle_validation_errors():
    eng = _engine("gdn")
    with pytest.raises(KeyError):
        eng.pause(7)                            # unknown rid
    with pytest.raises(KeyError):
        eng.resume(7)
    with pytest.raises(KeyError):
        eng.touch(7)
    with pytest.raises(KeyError):
        eng.preempt(rid=7)
    req = _reqs(1, False)[0]
    eng.submit(req)
    with pytest.raises(ValueError, match="already live"):
        eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32)))
    eng.pause(0)
    with pytest.raises(ValueError, match="already live"):
        eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32)))
    with pytest.raises(ValueError, match="already swapped"):
        eng.pause(0)
    eng.resume(0)
    eng.run_until_done()
    assert req.done
    # a finished rid may recur — sessions reconnect
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))
    eng.run_until_done()

    eng = _engine("gdn")
    req = _reqs(1, False)[0]
    eng.submit(req)
    _step_until(eng, lambda: req.state == sched.ACTIVE)
    eng.preempt()
    with pytest.raises(ValueError, match="already resuming"):
        eng.resume(0)
    assert eng.pause(0) is req                  # resuming -> dormant
    assert req.state == sched.SWAPPED and not eng.resume_q
    eng.resume(0)
    eng.run_until_done()
    assert req.done


def test_policy_and_cap_validation():
    cfg, params = _model("gdn")
    with pytest.raises(ValueError, match="swap_policy"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     swap_policy="lru")
    with pytest.raises(ValueError, match="idle_swap_ms"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     swap_policy="idle")
    with pytest.raises(ValueError, match="idle_swap_ms"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     swap_policy="auto", idle_swap_ms=-1.0)
    with pytest.raises(ValueError, match="max_live_requests"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     max_live_requests=0)


def test_max_live_requests_counts_swapped():
    """The admission cap bounds host memory: swapped-out sessions count
    as live, and a finished one frees its seat."""
    eng = _engine("gdn", max_live_requests=2)
    reqs = _reqs(2, False, max_new=2)
    for r in reqs:
        eng.submit(r)
    eng.pause(0)                                # swapped still counts
    with pytest.raises(RuntimeError, match="max_live_requests"):
        eng.submit(Request(rid=9, prompt=np.arange(1, 5, dtype=np.int32)))
    eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    eng.submit(Request(rid=9, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=2))       # seats freed by completion
    eng.run_until_done()


# --------------------------------------------------------- async paging

def _ring_ledger_ok(eng):
    """Gather-ring ledger invariant: free tickets and draining tickets
    partition the ring — a draining buffer is never re-issued."""
    ex = eng.executor
    free = set(ex._gather_free)
    pend = set(ex._gather_pending)
    assert not (free & pend)
    assert free | pend == set(range(ex.gather_ring))
    assert set(eng._draining_q) == {
        rid for rid, rec in eng.swapped.items() if rec.pending is not None}
    return True


@pytest.mark.parametrize("kind", KINDS)
def test_async_paging_bitwise(kind):
    """Async paging moves only the WAIT, never a value: a mixed
    greedy+stochastic batch paused and resumed mid-decode under
    background-drained swaps is bitwise the uninterrupted dedicated-slot
    run — for every mixer family.  (Gather outputs snapshot the slot at
    dispatch; sync vs async differ only in when device_get happens.)"""
    ref = _ref_streams(kind, True)
    eng = _engine(kind, async_paging=True)
    reqs = _reqs(3, True)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (reqs[0].state == sched.ACTIVE
                              and len(reqs[0].output) >= 2))
    eng.pause(0)
    assert eng.swapped[0].phase == sched.DRAINING   # not yet harvested
    assert _ring_ledger_ok(eng)
    eng.step()                      # harvest sweep lands the drain
    eng.step()
    eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref
    m = eng.metrics()
    assert m["async_paging"] == 1
    assert m["swap_harvests_overlapped"] + m["swap_harvests_forced"] \
        == m["swap_outs"] >= 1
    assert _ring_ledger_ok(eng)


def test_async_ring_pressure_forces_harvest():
    """More concurrent drains than gather buffers: the dispatch that
    would overflow the ring force-harvests the oldest drain first — the
    ledger holds at every point and the streams still match."""
    ref = _ref_streams("gdn", False)
    eng = _engine("gdn", async_paging=True, gather_ring=1)
    reqs = _reqs(3, False)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: len(eng.active) == 2)
    first = sorted(r.rid for r in eng.active.values())
    eng.pause(first[0])             # fills the 1-deep ring
    assert eng.swapped[first[0]].phase == sched.DRAINING
    assert _ring_ledger_ok(eng)
    eng.pause(first[1])             # must force-harvest the first drain
    assert eng.swapped[first[0]].phase == sched.HOSTED
    assert eng.swapped[first[1]].phase == sched.DRAINING
    assert _ring_ledger_ok(eng)
    assert eng.swap_harvests_forced >= 1
    for rid in (first[0], first[1]):
        eng.resume(rid)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref


def test_async_prefetch_consumed_and_cancelled():
    """A predictable resume grant prestages its H2D put one tick ahead
    and the grant consumes it; pausing the resuming request instead
    drops the prefetch cleanly (no grant ever sees a stale image)."""
    ref = _ref_streams("gdn", True)
    eng = _engine("gdn", async_paging=True)
    reqs = _reqs(3, True)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (reqs[0].state == sched.ACTIVE
                              and len(reqs[0].output) >= 2))
    eng.pause(0)
    eng.step()
    eng.resume(0)
    # no free slot yet: step until the head resume claim is prefetched
    _step_until(eng, lambda: (0 not in eng.swapped
                              or eng.swapped[0].prefetch is not None))
    if 0 in eng.swapped:
        assert eng.swapped[0].phase == sched.PREFETCHED
        eng.pause(0)                # cancelled resume drops the triple
        assert eng.swapped[0].prefetch is None
        assert eng.swapped[0].phase == sched.HOSTED
        assert eng.swap_prefetch_drops == 1
        eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref
    m = eng.metrics()
    assert m["swap_prefetches"] >= 1


def test_swap_timing_split_and_parked_from_dispatch():
    """swap_s == swap_dispatch_s + swap_stall_s on both paths; the sync
    fallback books every harvest as a forced stall while async books
    background-completed drains as overlapped; and parked time spans
    gather DISPATCH -> restore scatter, so a drain harvested late never
    inflates reported throughput."""
    for async_paging in (False, True):
        eng = _engine("gdn", async_paging=async_paging)
        reqs = _reqs(2, False)
        for r in reqs:
            eng.submit(r)
        _step_until(eng, lambda: (reqs[0].state == sched.ACTIVE
                                  and len(reqs[0].output) >= 2))
        eng.pause(0)
        time.sleep(0.05)            # drains in the background, unharvested
        eng.resume(0)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        m = eng.metrics()
        assert m["swap_s"] == pytest.approx(
            m["swap_dispatch_s"] + m["swap_stall_s"])
        assert m["swap_s"] == pytest.approx(
            m["swap_gather_s"] + m["swap_put_s"] + m["swap_scatter_s"])
        # parked from dispatch: the 50 ms sleep is swapped-out time even
        # though the async harvest only happened at the resume step
        assert reqs[0].swapped_s >= 0.05
        if async_paging:
            assert m["swap_harvests_overlapped"] >= 1
            assert m["swap_overlap_ratio"] > 0
        else:
            assert m["swap_harvests_overlapped"] == 0
            assert m["swap_overlap_ratio"] == 0
            assert m["swap_stall_s"] > 0


def test_router_sums_swap_split_and_migration_waits_for_harvest():
    """Router metrics sum the dispatch/stall split, and a swapped-state
    migration force-harvests a still-draining gather so the record moves
    with a complete in-memory image — restored bitwise on the taker."""
    cfg, params = _model("gdn")
    ref_eng = _engine("gdn", max_slots=1)
    ref_req = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                      max_new_tokens=12, temperature=0.8, top_k=10,
                      top_p=0.9)
    ref_eng.submit(ref_req)
    ref_eng.run_until_done()

    engs = [DecodeEngine(cfg, params, max_slots=1, max_len=64,
                         decode_block=2, prefill_chunk=8,
                         async_paging=True) for _ in range(2)]
    router = Router(engs, policy="round_robin")
    a = Request(rid=0, prompt=np.arange(1, 9, dtype=np.int32),
                max_new_tokens=12, temperature=0.8, top_k=10, top_p=0.9)
    router.submit(a)
    engs[0].step()
    assert a.state == sched.ACTIVE
    router.pause(0)
    assert engs[0].swapped[0].phase == sched.DRAINING
    router.resume(0)
    # withdraw while the gather is STILL draining (no tick ran a harvest
    # sweep in between): migration must force the harvest itself
    assert engs[0].swapped[0].phase == sched.DRAINING
    rec = engs[0].withdraw_swapped()
    assert rec is not None
    assert rec.pending is None and rec.prefetch is None     # harvested
    assert isinstance(rec.state, SwappedState)
    hog = Request(rid=10, prompt=np.arange(1, 9, dtype=np.int32),
                  max_new_tokens=24)
    engs[0].submit(hog)
    engs[1].readmit_swapped(rec)
    done = router.run_until_done()
    assert {r.rid for r in done} == {0, 10}
    assert list(a.output) == list(ref_req.output)
    m = router.metrics()
    assert m["swap_dispatch_s"] > 0
    assert m["swap_s"] == pytest.approx(m["swap_dispatch_s"]
                                        + m["swap_stall_s"])
    assert (m["swap_harvests_overlapped"] + m["swap_harvests_forced"]
            == m["swap_outs"])


# -------------------------------------------------------- spill-to-disk

def test_spill_lifecycle(tmp_path):
    """Beyond the watermark the coldest dormant image spills to a
    wire-encoded ``swap-<rid>.state`` under the spool dir (state leaves
    host memory), and resume reloads
    it transparently — the stream is still bitwise the uninterrupted
    one and the spool file is deleted."""
    ref = _ref_streams("gdn", True)
    spool = str(tmp_path / "spool")
    eng = _engine("gdn", async_paging=True, swap_spool_dir=spool,
                  host_swap_bytes=0)
    reqs = _reqs(3, True)
    for r in reqs:
        eng.submit(r)
    _step_until(eng, lambda: (reqs[0].state == sched.ACTIVE
                              and len(reqs[0].output) >= 2))
    eng.pause(0)
    # dormant + over-watermark: the next tick harvests then spills
    _step_until(eng, lambda: eng.swapped[0].phase == sched.SPILLED)
    rec = eng.swapped[0]
    assert rec.state is None and rec.pending is None
    assert os.path.exists(rec.spool)
    assert rec.spool.startswith(spool)
    m = eng.metrics()
    assert m["spills"] == 1 and m["spill_bytes"] > 0
    assert m["host_swap_bytes_held"] == 0
    eng.resume(0)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    assert _streams(reqs) == ref
    m = eng.metrics()
    assert m["spill_loads"] == 1
    assert not os.listdir(spool)        # reload deleted the file


def test_spill_validation_and_ring_validation():
    cfg, params = _model("gdn")
    with pytest.raises(ValueError, match="host_swap_bytes"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     host_swap_bytes=-1, swap_spool_dir="/tmp/x")
    with pytest.raises(ValueError, match="swap_spool_dir"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     host_swap_bytes=1 << 20)
    with pytest.raises(ValueError, match="gather_ring"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     gather_ring=0)
