"""Dry-run machinery: input_specs, cell lowering, hlo cost extraction, and
the collective parser — on a reduced 8-device mesh in a subprocess (the
512-device production sweep runs via `python -m repro.launch.dryrun`; its
results are validated in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.launch import steps as steps_mod


def test_input_specs_all_cells():
    """Every (arch x shape) cell has well-formed ShapeDtypeStruct inputs."""
    for name in configs.ARCHS:
        cfg = configs.get_arch(name)
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape_name)
            if not ok:
                assert "full attention" in why or "quadratic" in why
                continue
            spec = steps_mod.input_specs(cfg, shape)
            leaves = jax.tree.leaves(spec)
            assert leaves, (name, shape_name)
            assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
            if shape.kind == "decode":
                assert spec["tokens"].shape == (shape.global_batch,)
            if cfg.frontend_stub and shape.kind != "decode":
                key = "batch" if shape.kind == "train" else None
                d = spec[key] if key else spec
                assert "embeds" in d          # stub frontend contract


def test_long500k_skips_are_exactly_the_full_attention_archs():
    skips = {n for n in configs.ARCHS
             if not shape_applicable(configs.get_arch(n), "long_500k")[0]}
    assert skips == {"llava-next-34b", "minicpm-2b", "minitron-8b",
                     "yi-9b", "musicgen-medium", "arctic-480b"}


def test_microbatch_sizing():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = configs.get_arch("arctic-480b")
    mb = steps_mod.microbatches_for(cfg, SHAPES["train_4k"], mesh)
    assert mb >= 1
    # big archs get bf16/factored optimizer state
    ac = steps_mod.adamw_config_for(cfg)
    assert ac.factored and not ac.momentum
    ac_small = steps_mod.adamw_config_for(configs.get_arch("minicpm-2b"))
    assert ac_small.momentum and ac_small.moment_dtype == "float32"


SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["DRYRUN_DIR"] = os.environ.get("TEST_TMP", "/tmp") + "/dr"
    import jax, json
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.launch import steps as sm, hlo_cost
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = configs.get_arch("qwen3-next-gdn")
    # small cell: decode against a 2k cache, batch 8
    shape = ShapeConfig("mini_decode", 2048, 8, "decode")
    lowered = sm.lower_cell(cfg, shape, mesh)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    # newer jax drops peak_memory_in_bytes (same compat guard as dryrun.py)
    peak = getattr(mem, "peak_memory_in_bytes", 0) or (
        mem.temp_size_in_bytes + mem.output_size_in_bytes)
    assert peak > 0
    cost = hlo_cost.analyze(compiled.as_text())
    assert cost["bytes"] > 0
    assert cost["flops"] > 0
    print("DRYRUN_SUB_OK", int(cost["bytes"]))
""")


def test_lower_cell_small_mesh_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src", TEST_TMP=str(tmp_path))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUB], capture_output=True,
                       text=True, env=env, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "DRYRUN_SUB_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-4000:]


def test_sweep_results_complete_and_green():
    """The committed production sweep must cover all 88 cells, no errors."""
    d = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "experiments", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("sweep results not present")
    cells = [json.load(open(os.path.join(d, f))) for f in os.listdir(d)
             if f.endswith(".json")]
    assert len(cells) == 88
    assert all(c["status"] in ("ok", "skipped") for c in cells)
    oks = [c for c in cells if c["status"] == "ok"]
    assert len(oks) == 76
    assert all(c["fits_hbm_16g"] for c in oks)
    assert {c["mesh"] for c in oks} == {"single", "multi"}
