"""hlo_cost: trip-count-aware FLOP/byte/collective accounting vs known programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_cost


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    txt = compile_text(lambda a, b: a @ b, a, b)
    out = hlo_cost.analyze(txt)
    assert out["flops"] == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_scan_multiplies_trip_count():
    w = jax.ShapeDtypeStruct((10, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f(x, w):
        def body(c, wi):
            return c @ wi, None
        y, _ = jax.lax.scan(body, x, w)
        return y

    out = hlo_cost.analyze(compile_text(f, x, w))
    expected = 10 * 2 * 128 ** 3
    assert out["flops"] == pytest.approx(expected, rel=0.01)


def test_nested_scan():
    w = jax.ShapeDtypeStruct((4, 3, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, w):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    out = hlo_cost.analyze(compile_text(f, x, w))
    assert out["flops"] == pytest.approx(12 * 2 * 64 ** 3, rel=0.01)


def test_batched_dot_flops():
    a = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 32, 16), jnp.float32)
    txt = compile_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    out = hlo_cost.analyze(txt)
    assert out["flops"] == pytest.approx(2 * 8 * 64 * 32 * 16, rel=0.01)


def test_bytes_scale_with_scan():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)

    def f(x):
        def body(c, _):
            return c * 1.0001 + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=16)
        return y

    out = hlo_cost.analyze(compile_text(f, x))
    # 16 iterations each read+write ~4MB
    assert out["bytes"] >= 16 * 2 * 1024 * 1024 * 4 * 0.9


def test_collectives_trip_scaled():
    import os
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.launch import hlo_cost
mesh = jax.make_mesh((8,), ("model",))
w = jax.ShapeDtypeStruct((4, 256, 256), jnp.float32)
x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
def f(x, w):
    def body(c, wi):
        return c @ wi, None
    y, _ = jax.lax.scan(body, x, w)
    return y
with mesh:
    j = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, None)),
                                 NamedSharding(mesh, P(None, None, "model"))),
                out_shardings=NamedSharding(mesh, P(None, None)))
    txt = j.lower(x, w).compile().as_text()
out = hlo_cost.analyze(txt)
coll = out["collectives"]["total"]
# 4 iterations + final: all-gather of the per-device shard 128 x 32 fp32
assert coll >= 5 * 128 * 32 * 4 * 0.9, coll
assert coll <= 6 * 128 * 256 * 4, coll
print("OK", coll)
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.getcwd(),
                       env={**os.environ, "PYTHONPATH": "src"})
    assert "OK" in r.stdout, r.stdout + r.stderr
