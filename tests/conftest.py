import pytest  # noqa: F401


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "subprocess: spawns EngineWorker subprocesses (each builds its "
        "own jax runtime — the multiprocess disagg smoke; select with "
        "-m subprocess)")
