"""Device-resident decode loop: k fused steps == k single steps.

Pins the scan-batched tick (``lm.decode_steps``) to the single-step path
token-for-token and cache-bitwise, and the engine's ``decode_block`` to
the k=1 engine, so fusing the hot loop can never change what is served.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


@pytest.fixture(scope="module")
def gdn_model():
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_decode_steps_matches_single_steps_bitwise(gdn_model):
    """One k=4 scan == 4 decode_step calls: same tokens, bitwise caches."""
    cfg, params = gdn_model
    B, T, k = 2, 8, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 1, cfg.vocab)

    caches = lm.init_caches(cfg, B, 32)
    logits, caches = lm.prefill(params, cfg, caches, tokens=tokens)
    first = jnp.argmax(logits, -1).astype(jnp.int32)

    # reference: k greedy single steps
    ref_caches, cur, ref_toks = caches, first, []
    for _ in range(k):
        logits, ref_caches = lm.decode_step(params, cfg, cur, ref_caches)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        ref_toks.append(cur)

    # fused: one k-step scan (default greedy sampler)
    toks, valid, last, scan_caches, _ = lm.decode_steps(
        params, cfg, first, caches, k)

    assert bool(valid.all())
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.stack(ref_toks)))
    np.testing.assert_array_equal(np.asarray(last), np.asarray(ref_toks[-1]))
    for a, b in zip(jax.tree.leaves(scan_caches),
                    jax.tree.leaves(ref_caches)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_steps_masks_done_slots(gdn_model):
    """A slot whose done flag is set re-feeds its token: its emissions are
    invalid and its token stream is frozen."""
    cfg, params = gdn_model
    B = 2
    caches = lm.init_caches(cfg, B, 32)
    tokens = jnp.asarray([3, 5], jnp.int32)

    def sample_fn(st, logits):
        return jnp.argmax(logits, -1).astype(jnp.int32), st

    sampler = {"done": jnp.asarray([False, True])}
    toks, valid, last, _, _ = lm.decode_steps(
        params, cfg, tokens, caches, 3, sampler=sampler,
        sample_fn=sample_fn)
    valid = np.asarray(valid)
    toks = np.asarray(toks)
    assert valid[:, 0].all() and not valid[:, 1].any()
    assert (toks[:, 1] == 5).all()           # frozen
    assert int(last[1]) == 5


def _engine_outputs(cfg, params, k, *, stochastic=False, eos=None):
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=k)
    reqs = [Request(rid=i, prompt=np.arange(1, 6 + i, dtype=np.int32),
                    max_new_tokens=5 + i,
                    temperature=0.8 if stochastic else 0.0,
                    top_k=10 if stochastic else 0,
                    top_p=0.9 if stochastic else 1.0,
                    eos_id=eos)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.output) for r in reqs]


def test_engine_block_parity_greedy(gdn_model):
    """k-token ticks emit exactly what k single-token ticks emit."""
    cfg, params = gdn_model
    eng1, out1 = _engine_outputs(cfg, params, 1)
    eng4, out4 = _engine_outputs(cfg, params, 4)
    assert out1 == out4
    assert all(len(o) == 5 + i for i, o in enumerate(out1))
    assert eng4.ticks < eng1.ticks           # fewer host syncs


def test_engine_block_parity_stochastic(gdn_model):
    """Per-request device RNG streams make sampled outputs identical
    across decode_block too."""
    cfg, params = gdn_model
    _, out1 = _engine_outputs(cfg, params, 1, stochastic=True)
    _, out3 = _engine_outputs(cfg, params, 3, stochastic=True)
    assert out1 == out3


def test_engine_eos_mid_block(gdn_model):
    """EOS landing mid-block stops the request at the same token as k=1,
    and the freed slot is reused."""
    cfg, params = gdn_model
    # learn the greedy stream, then declare its 3rd token to be EOS
    _, ref = _engine_outputs(cfg, params, 1)
    eos = ref[0][2]
    _, out1 = _engine_outputs(cfg, params, 1, eos=eos)
    _, out4 = _engine_outputs(cfg, params, 4, eos=eos)
    assert out1 == out4
    for o in out1:
        assert eos not in o[:-1]             # nothing emitted past EOS


def test_engine_metrics(gdn_model):
    cfg, params = gdn_model
    eng, _ = _engine_outputs(cfg, params, 4)
    m = eng.metrics()
    assert m["requests"] == 4
    assert m["decode_block"] == 4
    assert m["tokens"] == sum(5 + i for i in range(4))
    assert m["decoded_tokens"] == m["tokens"] - 4   # admit emits 1 each
    assert m["decode_s"] > 0 and m["decode_us_per_token"] > 0
    assert m["mean_ttft_s"] > 0
    assert m["mean_latency_s"] >= m["mean_ttft_s"]
    assert m["mean_tokens_per_s"] > 0
    for r in eng._all:
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.latency_s is not None and r.latency_s >= r.ttft_s
