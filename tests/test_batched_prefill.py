"""Batched multi-prompt prefill: one fixed-shape varlen program per tick.

The batched packer fuses every staged prompt into ONE
(staging_depth, _MAX_SCAN_CHUNKS, prefill_chunk) scan + ONE admit
program per dispatch (rows past a prompt's end are valid_len = 0
bitwise no-ops) and admits every finished row through ONE multi-row
scatter.  Every guarantee that fusion rests on is pinned here:

  * kernel parity — interpret-mode Pallas ``gdn_prefill`` with per-row
    *different* valid_lens (including a valid = 0 placeholder row)
    equals the row-by-row sequential oracle, and the placeholder row's
    state is untouched;
  * engine parity — batched token streams are bitwise identical to the
    per-prompt (``prefill_batching=False``) baseline for every mixer
    kind, greedy and stochastic, overlapped and serialized, across
    mixed ragged prompt lengths, ring depths and packer budgets
    (``admit_rows`` folds the same (seed, rid) keys as ``admit_row``,
    so draw streams are batching-invariant);
  * O(1) dispatch shapes — one engine serving every awkward length
    compiles ≤ 2 batched prefill programs (vs ≤ 5 per-prompt);
  * batch-admit semantics — rows admitted by one dispatch share ONE
    device sync and stamp the SAME ``t_first``; finished rows scatter
    in ONE multi-row dispatch;
  * fairness — strict oldest-first packing: a long staged prompt
    drains at full rate no matter how many short prompts arrive behind
    it (its dispatch count is bounded by its own chunk count);
  * gates — MoE FFNs and mixer kinds without per-row masks fall back
    to per-prompt staging (silently on auto, loudly when forced);
  * mesh — data-sharded batched serving stays bitwise; the
    head-sharded (4, 2) topology completes (subprocess, 8 virtual
    devices).

The CI kernel-path job re-runs this module with REPRO_PALLAS_SERVING=1
so the batched rows drive the Pallas prefill kernels (interpret mode).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import gdn as gdn_core
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request

ARCHS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}


def _arch_cfg(name):
    cfg = configs.get_arch(name).reduced()
    if os.environ.get("REPRO_PALLAS_SERVING") == "1":
        cfg = cfg.replace(use_pallas_serving=True)
    return cfg


@pytest.fixture(scope="module")
def gdn_model():
    cfg = _arch_cfg(ARCHS["gdn"])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("delta_rule", [True, False], ids=["gdn", "ssd"])
def test_gdn_prefill_kernel_multirow_ragged(delta_rule):
    """Per-row DIFFERENT valid_lens — the exact operand the batched
    staging rows feed the kernel — match the row-by-row sequential
    oracle, and a valid = 0 placeholder row leaves its state bitwise
    untouched (the no-op guarantee the fixed-shape dispatch rests
    on)."""
    from repro.kernels.gdn_prefill import gdn_prefill_pallas
    rng = np.random.default_rng(7)
    BH, T, dk, dv, C = 4, 16, 8, 8, 4
    valids = np.array([3, 16, 0, 11], np.int32)     # ragged + placeholder
    q = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, dv)), jnp.float32)
    lg = jnp.asarray(-np.abs(rng.normal(size=(BH, T))), jnp.float32)
    b = jax.nn.sigmoid(jnp.asarray(rng.normal(size=(BH, T)), jnp.float32))
    S0 = jnp.asarray(rng.normal(size=(BH, dk, dv)), jnp.float32)

    O, S = gdn_prefill_pallas(q, k, v, lg, b, S0, jnp.asarray(valids),
                              chunk=C, delta_rule=delta_rule,
                              interpret=True)
    for h, valid in enumerate(valids):
        if valid == 0:
            np.testing.assert_array_equal(np.asarray(S[h]),
                                          np.asarray(S0[h]))
            continue
        Oref, Sref = gdn_core.prefill_sequential(
            q[h, :valid], k[h, :valid], v[h, :valid], lg[h, :valid],
            b[h, :valid], S0[h], delta_rule=delta_rule)
        np.testing.assert_allclose(np.asarray(O[h, :valid]),
                                   np.asarray(Oref), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(S[h]), np.asarray(Sref),
                                   rtol=2e-5, atol=2e-5)


# ------------------------------------------------------- engine parity

# mixed ragged lengths with prefill_chunk=8: tail-only (6), scan+tail
# (17), exact chunk (8), multi-scan (26), single token (1), mid (13)
_LENS = (6, 17, 8, 26, 1, 13)


def _serve(cfg, params, *, batching, overlap=True, stochastic=False,
           depth=3, budget=None, slots=2):
    eng = DecodeEngine(cfg, params, max_slots=slots, max_len=64,
                       decode_block=4, overlap=overlap, prefill_chunk=8,
                       staging_depth=depth, prefill_batching=batching,
                       prefill_budget=budget)
    reqs = [Request(rid=i, prompt=np.arange(1, L + 1, dtype=np.int32),
                    max_new_tokens=3 + i,
                    temperature=0.8 if stochastic else 0.0,
                    top_k=10 if stochastic else 0,
                    top_p=0.9 if stochastic else 1.0)
            for i, L in enumerate(_LENS)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.output) for r in reqs]


@pytest.mark.parametrize("kind", sorted(ARCHS) + ["gdn_naive"])
def test_batched_streams_match_per_prompt(kind):
    """The tentpole guarantee: fusing all staged prompts into one
    fixed-shape program per dispatch never changes a token — batched
    streams are bitwise the per-prompt baseline's for every mixer kind,
    greedy AND stochastic."""
    arch = ARCHS.get(kind, ARCHS["gdn"])
    cfg = _arch_cfg(arch)
    if kind == "gdn_naive":
        cfg = cfg.replace(pattern=tuple(
            "gdn_naive" if k == "gdn" else k for k in cfg.pattern))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    e_per, s_per = _serve(cfg, params, batching=False)
    e_bat, s_bat = _serve(cfg, params, batching=None)   # auto -> on
    assert not e_per.prefill_batching and e_bat.prefill_batching
    assert s_bat == s_per
    _, st_per = _serve(cfg, params, batching=False, stochastic=True)
    _, st_bat = _serve(cfg, params, batching=None, stochastic=True)
    assert st_bat == st_per


def test_batched_parity_across_knobs(gdn_model):
    """Ring depth, packer budget and overlap are pure scheduling knobs
    of the batched path: streams equal the serialized per-prompt
    baseline under every combination."""
    cfg, params = gdn_model
    _, base = _serve(cfg, params, batching=False, overlap=False)
    for kw in ({"overlap": False}, {"depth": 1}, {"depth": 4},
               {"budget": 8}, {"budget": 24}, {"slots": 1}):
        _, out = _serve(cfg, params, batching=True, **kw)
        assert out == base, f"batched diverged under {kw}"
    _, st_base = _serve(cfg, params, batching=False, overlap=False,
                        stochastic=True)
    _, st_bud = _serve(cfg, params, batching=True, budget=8,
                       stochastic=True)
    assert st_bud == st_base


def test_batched_compile_cache_o1(gdn_model):
    """One engine serving every awkward prompt length compiles at most
    2 batched prefill programs (one fixed-shape scan + one admit) — the
    fixed five-phase iteration regardless of occupancy, tighter than
    the per-prompt masked planner's ≤ 5."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=8)
    assert eng.prefill_batching
    for rid, T in enumerate((1, 7, 8, 9, 23, 40, 41, 57)):
        eng.submit(Request(rid=rid, prompt=np.arange(1, T + 1,
                                                     dtype=np.int32),
                           max_new_tokens=2))
    eng.run_until_done()
    progs = eng.executor.compiled_programs()
    assert progs["prefill"] <= 2, progs
    assert eng.metrics()["prefill_programs"] == progs["prefill"]
    assert eng.metrics()["prefill_batching"] == 1


# --------------------------------------------- batch-admit semantics

def test_batch_admit_shares_t_first(gdn_model):
    """Rows admitted by one batched dispatch are one device event: both
    requests sync through the SAME host read and stamp the SAME
    ``t_first`` (serial stamps would skew TTFT for all but the first
    row)."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8)
    reqs = [Request(rid=i, prompt=np.arange(1, 18, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert all(r.t_first is not None for r in reqs)
    assert reqs[0].t_first == reqs[1].t_first


def test_multirow_scatter_single_dispatch(gdn_model):
    """Every finished staging row enters its slot in ONE dispatch: two
    simultaneously-admitted requests cost one scatter (the per-prompt
    path pays one per request), and the prefill itself costs one scan +
    one admit dispatch regardless of row count."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8)
    reqs = [Request(rid=i, prompt=np.arange(1, 18, dtype=np.int32),
                    max_new_tokens=6) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.scatter_dispatches == 1
    assert eng.stage_dispatches == 2        # one bscan + one badmit
    eng.run_until_done()
    assert all(r.done for r in reqs)

    per = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8,
                       prefill_batching=False)
    reqs2 = [Request(rid=i, prompt=np.arange(1, 18, dtype=np.int32),
                     max_new_tokens=6) for i in range(2)]
    for r in reqs2:
        per.submit(r)
    per.run_until_done()
    assert per.scatter_dispatches == 2
    assert per.stage_dispatches == 4
    assert [r.output for r in reqs2] == [r.output for r in reqs]


def test_fairness_long_prompt_drains_oldest_first(gdn_model):
    """Strict oldest-first packing: under saturation with a 1-chunk
    budget and short prompts arriving continuously behind it, a long
    staged prompt still drains one chunk every tick — its first token
    lands within (chunks + 1) saturated ticks and BEFORE any
    later-arriving short prompt's, so its dispatch count is bounded by
    its own chunk count."""
    cfg, params = gdn_model
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=4, overlap=True, prefill_chunk=8,
                       staging_depth=2, prefill_budget=8)
    busy = Request(rid=99, prompt=np.arange(1, 9, dtype=np.int32),
                   max_new_tokens=50)
    eng.submit(busy)
    eng.step()                                  # slot busy, long budget
    long = Request(rid=0, prompt=np.arange(1, 58, dtype=np.int32),
                   max_new_tokens=4)            # 57 tokens = 7 chunks + 1
    eng.submit(long)
    shorts = []
    ticks = 0
    while long.t_first is None and ticks < 12:
        s = Request(rid=1 + ticks, prompt=np.arange(1, 7, dtype=np.int32),
                    max_new_tokens=2)
        eng.submit(s)                           # continuous arrivals
        shorts.append(s)
        eng.step()
        ticks += 1
    assert long.t_first is not None, "long prompt starved"
    assert ticks <= 9, f"long prompt took {ticks} saturated ticks"
    assert all(s.t_first is None for s in shorts), \
        "a younger short prompt was admitted before the older long one"
    eng.run_until_done(max_ticks=50_000)
    assert long.done and busy.done and all(s.done for s in shorts)


# --------------------------------------------------------------- gates

def test_capability_flag_gates_batching(gdn_model, monkeypatch):
    """A mixer kind without per-row (B,) valid_len support keeps the
    engine on per-prompt staging: silently on auto, with a loud warning
    when batching is forced — and it still serves."""
    from repro.models.mixers.gdn import GatedDeltaNet
    cfg, params = gdn_model
    monkeypatch.setattr(GatedDeltaNet, "supports_batched_ragged_prefill",
                        False)
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=8)
    assert not eng.prefill_batching         # auto: silent fallback
    with pytest.warns(RuntimeWarning, match="prefill_batching disabled"):
        eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                           decode_block=1, prefill_chunk=8,
                           prefill_batching=True)
    assert not eng.prefill_batching
    eng.submit(Request(rid=0, prompt=np.arange(1, 12, dtype=np.int32),
                       max_new_tokens=2))
    assert all(r.done for r in eng.run_until_done())


def test_moe_gate_disables_batching():
    """MoE expert-capacity dispatch couples rows within a batch (the
    cumsum queue), so batched prefill cannot be bitwise — the gate
    keeps MoE archs on per-prompt staging."""
    cfg = configs.get_arch("mixtral-8x7b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=8)
    assert not eng.prefill_batching
    with pytest.warns(RuntimeWarning, match="expert-capacity"):
        eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                           decode_block=1, prefill_chunk=8,
                           prefill_batching=True)
    assert not eng.prefill_batching


def test_prefill_budget_validation(gdn_model):
    cfg, params = gdn_model
    with pytest.raises(ValueError, match="prefill_budget"):
        DecodeEngine(cfg, params, max_slots=1, max_len=32,
                     prefill_budget=0)


# ----------------------------------------- multi-device (subprocess, 8x)

SUBPROCESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro import configs
    from repro.models import lm
    from repro.serving.engine import DecodeEngine, Request

    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)

    def serve(mesh, batching, stochastic, depth=2):
        eng = DecodeEngine(cfg, params, max_slots=8, max_len=64,
                           decode_block=4, prefill_chunk=8, mesh=mesh,
                           staging_depth=depth, prefill_batching=batching)
        reqs = [Request(rid=i,
                        prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                        max_new_tokens=4 + i,
                        temperature=0.8 if stochastic and i % 2 else 0.0,
                        top_k=10 if stochastic and i % 2 else 0)
                for i in range(6)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        return eng, [list(r.output) for r in reqs]

    # --- 1. data-sharded batched serving is bitwise: 8-device mesh,
    #        batched == per-prompt == 1-device baseline, greedy and
    #        stochastic, at a dividing (8) and a non-dividing (2,
    #        row-replicated) staging depth
    mesh1 = jax.make_mesh((1, 1), ("data", "model"),
                          devices=jax.devices()[:1])
    mesh8 = jax.make_mesh((8, 1), ("data", "model"))
    for stochastic in (False, True):
        _, base = serve(mesh1, None, stochastic)
        _, per8 = serve(mesh8, False, stochastic)
        assert per8 == base
        for depth in (2, 8):
            eng8, bat8 = serve(mesh8, None, stochastic, depth=depth)
            assert eng8.prefill_batching
            assert bat8 == base, (
                f"batched slot-axis DP must be bitwise "
                f"(stochastic={stochastic}, depth={depth})")

    # --- 2. batched staging rows shard on "data" when the depth
    #        divides, and never land a DP axis on a state dim otherwise
    def ax(e):
        return () if e is None else (e if isinstance(e, tuple) else (e,))
    eng8, _ = serve(mesh8, None, False, depth=8)
    flat, _ = jax.tree_util.tree_flatten_with_path(eng8.executor.bstaging)
    from repro.parallel import sharding as rules
    spec_of = {rules.path_str(p): l.sharding.spec for p, l in flat}
    s_specs = [s for p, s in spec_of.items() if p.endswith("/S")]
    assert s_specs and all(ax(s[1]) == ("data",) for s in s_specs), s_specs
    eng2, _ = serve(mesh8, None, False, depth=2)
    flat2, _ = jax.tree_util.tree_flatten_with_path(eng2.executor.bstaging)
    assert all(not any("data" in ax(e) for e in l.sharding.spec)
               for _, l in flat2)

    # --- 3. head-sharded (4, 2): batched serving completes (model-axis
    #        psum ordering, checked at completion like any TP stack)
    mesh42 = jax.make_mesh((4, 2), ("data", "model"))
    eng42, out42 = serve(mesh42, None, False, depth=4)
    assert eng42.prefill_batching
    assert all(len(o) == 4 + i for i, o in enumerate(out42))

    print("SUBPROCESS_BATCHED_OK")
""")


def test_sharded_batched_serving_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_TEST],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1800)
    assert "SUBPROCESS_BATCHED_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]
