"""Beyond-paper bf16 recurrent state: traffic halves, accuracy quantified."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm


@pytest.mark.parametrize("arch", ["qwen3-next-gdn", "mamba2-1.3b"])
def test_bf16_state_decode_close_to_fp32(arch):
    cfg32 = configs.get_arch(arch).reduced()
    cfg16 = cfg32.replace(state_dtype="bfloat16")
    params = lm.init_lm(jax.random.PRNGKey(0), cfg32)
    B, T = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                cfg32.vocab)

    def rollout(cfg):
        caches = lm.init_caches(cfg, B, max_len=64)
        _, caches = lm.prefill(params, cfg, caches, tokens=tokens[:, :16])
        outs = []
        tok = tokens[:, 16]
        for t in range(6):
            logits, caches = lm.decode_step(params, cfg, tok, caches)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            outs.append(logits)
        return jnp.stack(outs), caches

    lo32, c32 = rollout(cfg32)
    lo16, c16 = rollout(cfg16)
    # state dtype halved, leaf by leaf (cache trees are structurally equal)
    n_state = 0
    for a32, a16 in zip(jax.tree.leaves(c32), jax.tree.leaves(c16)):
        if a16.dtype == jnp.bfloat16 and a32.dtype == jnp.float32:
            assert a32.nbytes == 2 * a16.nbytes
            n_state += 1
    assert n_state, "bf16 state not present"
    # logits stay close over a short greedy rollout
    rel = float(jnp.max(jnp.abs(lo16 - lo32))
                / (jnp.max(jnp.abs(lo32)) + 1e-9))
    assert rel < 0.08, f"bf16 state diverged: rel={rel}"
    # greedy tokens agree on the first decode steps
    assert jnp.array_equal(jnp.argmax(lo16[0], -1), jnp.argmax(lo32[0], -1))
