"""Disaggregated prefill/decode serving: process-boundary engines,
the wire codec, role-split handoffs, worker-death recovery and the
acceptance-adaptive speculative draft length.

The paper's capacity argument gives decode a dedicated datapath;
LoopLynx (PAPERS.md) scales it across devices with a spatial–temporal
split.  The serving analog pinned here: the engine boundary can be a
*process* boundary (``EngineWorker`` subprocess behind an
``EngineProxy``), engines specialize by role (``prefill`` engines pause
every request at the admit boundary; the router ships the swapped image
to a ``decode`` engine), and none of it may change a token:

  * the wire codec round-trips every mixer kind's ``SwappedState``
    bitwise (dtype, shape, treedef), plus ``Request`` and the framed
    pipe protocol — one serializer for RPC and the spill-to-disk spool;
  * disaggregated streams (prefill engine → handoff → decode engine)
    are bitwise the single-engine colocated streams for all five mixer
    kinds, greedy AND stochastic, including a request that finishes at
    the admit boundary and never hands off;
  * the same holds across real worker processes, with timing stamps
    (TTFT/latency) surviving the cross-process handoff;
  * a worker killed mid-run is detected (EOF on its channel), marked
    dead, and its still-queued requests re-home to live compatible
    engines and finish;
  * role/lifecycle errors: decode-role engines reject fresh prompts,
    all-decode router topologies are rejected, adaptive_k requires
    speculative;
  * acceptance-adaptive k_draft: self-draft (acceptance ~1) keeps the
    effective k at k_draft; an adversarial random-weights draft
    collapses it to 1 — with streams identical either way (the
    shared-key verify emits the same tokens at any k).
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.serving import wire
from repro.serving.engine import (DecodeEngine, EngineProxy, Request,
                                  Router, WorkerDied)
from repro.serving.executor import SwappedState
from repro.serving.scheduler import _Swapped

ARCHS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}
KINDS = list(ARCHS)

_MODELS = {}


def _model(kind):
    if kind not in _MODELS:
        cfg = configs.get_arch(ARCHS[kind]).reduced()
        if os.environ.get("REPRO_PALLAS_SERVING") == "1":
            cfg = cfg.replace(use_pallas_serving=True)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        _MODELS[kind] = (cfg, params)
    return _MODELS[kind]


def _engine(kind, **kw):
    cfg, params = _model(kind)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_len", 64)
    kw.setdefault("decode_block", 2)
    kw.setdefault("prefill_chunk", 8)
    return DecodeEngine(cfg, params, **kw)


def _reqs(n, max_new=8):
    """Mixed greedy/stochastic sessions plus one admit-boundary
    finisher (max_new_tokens=1 completes on the prefill engine and must
    never hand off)."""
    out = [Request(rid=i, prompt=np.arange(1, 7 + 3 * i, dtype=np.int32),
                   max_new_tokens=max_new + i,
                   temperature=0.8 if i % 2 == 0 else 0.0,
                   top_k=10 if i % 2 == 0 else 0,
                   top_p=0.9 if i % 2 == 0 else 1.0)
           for i in range(n)]
    out.append(Request(rid=n, prompt=np.arange(1, 9, dtype=np.int32),
                       max_new_tokens=1))
    return out


def _streams(reqs):
    return [list(r.output) for r in reqs]


_REF = {}


def _ref_streams(kind):
    """Single-engine colocated reference streams for ``_reqs(3)``."""
    if kind not in _REF:
        eng = _engine(kind)
        reqs = _reqs(3)
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.done for r in reqs)
        _REF[kind] = _streams(reqs)
    return _REF[kind]


# ======================================================================
# wire codec
# ======================================================================
def test_wire_scalars_containers_roundtrip():
    vals = [None, True, False, 0, -1, 2**40, 3.5, "héllo", b"\x00\xff",
            [1, "a", None], (2.5, (None,)), {"k": [1, 2], 3: "v"},
            {"nested": {"deep": (b"x", [True])}}]
    for v in vals:
        assert wire.decode(wire.encode(v)) == v
    # tuples and lists stay distinct
    assert isinstance(wire.decode(wire.encode((1, 2))), tuple)
    assert isinstance(wire.decode(wire.encode([1, 2])), list)
    # numpy scalar coercion
    assert wire.decode(wire.encode(np.int64(7))) == 7
    assert wire.decode(wire.encode(np.float32(0.5))) == 0.5


def test_wire_ndarray_bitwise():
    rng = np.random.default_rng(0)
    for arr in (rng.standard_normal((3, 5)).astype(np.float32),
                rng.standard_normal(7).astype(np.float64),
                rng.integers(0, 2**31, (2, 2)).astype(np.int32),
                rng.integers(0, 2**32, (1, 2)).astype(np.uint32),
                np.array([], dtype=np.float32),
                np.asarray(np.float16(1.5))):
        back = wire.decode(wire.encode(arr))
        assert back.dtype == arr.dtype and back.shape == arr.shape
        assert back.tobytes() == arr.tobytes()
    with pytest.raises(TypeError):
        wire.encode(np.array([object()], dtype=object))


def test_wire_frame_eof(tmp_path):
    p = tmp_path / "frames.bin"
    with open(p, "wb") as f:
        wire.write_frame(f, b"hello")
        wire.write_frame(f, b"")
    with open(p, "rb") as f:
        assert wire.read_frame(f) == b"hello"
        assert wire.read_frame(f) == b""
        with pytest.raises(EOFError):
            wire.read_frame(f)
    with open(p, "wb") as f:       # truncated payload
        f.write((99).to_bytes(8, "big") + b"short")
    with open(p, "rb") as f:
        with pytest.raises(EOFError):
            wire.read_frame(f)


def test_wire_request_roundtrip():
    req = Request(rid=42, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=7, temperature=0.8, top_k=10,
                  top_p=0.9, eos_id=3, priority=2,
                  output=[1, 2, 3], state="queued", t_submit=123.5)
    back = wire.decode_request(wire.encode_request(req))
    for f in dataclasses.fields(req):
        a, b = getattr(back, f.name), getattr(req, f.name)
        if isinstance(b, np.ndarray):
            assert a.dtype == b.dtype and np.array_equal(a, b), f.name
        else:
            assert a == b or (a is None and b is None), f.name
    assert back.prompt.dtype == req.prompt.dtype


def test_wire_request_update_applies_progress():
    a = Request(rid=1, prompt=np.arange(4, dtype=np.int32))
    b = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                output=[5, 6], done=True, state="done",
                t_submit=1.0, t_first=1.5, t_done=2.0, swapped_s=0.25)
    wire.apply_request_update(a, wire.request_update(b))
    for k in wire.REQUEST_SYNC_FIELDS:
        assert getattr(a, k) == getattr(b, k), k


@pytest.mark.parametrize("kind", KINDS)
def test_wire_swapped_state_bitwise(kind, tmp_path):
    """Per mixer kind: a synthetic SwappedState with every cache leaf
    randomly filled round-trips bitwise through the codec AND the
    on-disk spool image — dtypes, shapes and the pytree treedef exact.
    Built from ``cache_specs`` directly: no engine, no compile."""
    cfg, _ = _model(kind)
    spec = lm.cache_specs(cfg, 1, 64)
    rng = np.random.default_rng(hash(kind) % 2**31)

    def fill(leaf):
        x = np.asarray(leaf)
        if np.issubdtype(x.dtype, np.floating):
            return rng.standard_normal(x.shape).astype(x.dtype)
        return rng.integers(0, 100, x.shape).astype(x.dtype)

    caches = jax.tree.map(fill, jax.device_get(spec.zeros()))
    sampler = {"key": rng.integers(0, 2**32, (1, 2)).astype(np.uint32),
               "temperature": np.array([0.8], np.float32),
               "top_k": np.array([10], np.int32),
               "top_p": np.array([0.9], np.float32),
               "eos_id": np.array([-1], np.int32),
               "remaining": np.array([5], np.int32),
               "done": np.array([False])}
    sw = SwappedState(caches=caches, sampler=sampler,
                      token=np.array([[7]], np.int32))

    for back in (wire.decode_swapped(wire.encode_swapped(sw)),
                 _spool_roundtrip(tmp_path, kind, sw)):
        assert (jax.tree_util.tree_structure(back.caches)
                == jax.tree_util.tree_structure(caches))
        for got, want in zip(jax.tree_util.tree_leaves(back.caches),
                             jax.tree_util.tree_leaves(caches)):
            assert got.dtype == want.dtype and got.shape == want.shape
            assert np.array_equal(got, want), f"{kind}: leaf diverged"
        for k in sampler:
            assert np.array_equal(back.sampler[k], sampler[k]), k
            assert back.sampler[k].dtype == sampler[k].dtype, k
        assert np.array_equal(back.token, sw.token)


def _spool_roundtrip(tmp_path, kind, sw):
    path = str(tmp_path / f"swap-{kind}.state")
    wire.dump_swapped(path, sw)
    return wire.load_swapped(path)


def test_wire_swap_record_rejects_unharvested():
    req = Request(rid=0, prompt=np.arange(4, dtype=np.int32))
    rec = _Swapped(req=req, state=None, t_swap=1.0, pending=object())
    with pytest.raises(ValueError, match="harvested"):
        wire.encode_swap_record(rec)


# ======================================================================
# role lifecycle errors
# ======================================================================
def test_decode_role_rejects_fresh_prompts():
    eng = _engine("gdn", role="decode")
    with pytest.raises(ValueError, match="decode"):
        eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32)))


def test_bad_role_topologies_rejected():
    with pytest.raises(ValueError, match="role must be"):
        _engine("gdn", role="verifier")
    dec = _engine("gdn", role="decode")
    with pytest.raises(ValueError, match="decode-role"):
        Router([dec])
    pre = _engine("gdn", role="prefill")
    with pytest.raises(ValueError, match="decode-capable"):
        Router([pre])
    with pytest.raises(ValueError, match="speculative"):
        _engine("gdn", adaptive_k=True)


# ======================================================================
# in-process disaggregation: bitwise per mixer kind
# ======================================================================
@pytest.mark.parametrize("kind", KINDS)
def test_disagg_streams_bitwise(kind):
    """Prefill engine → admit-boundary pause → router handoff → decode
    engine restore must be bitwise the colocated single-engine streams,
    greedy and stochastic, for every mixer kind.  The admit-boundary
    finisher (max_new_tokens=1) completes on the prefill engine without
    a handoff; the prefill engine never runs a decode tick."""
    pre = _engine(kind, role="prefill")
    dec = _engine(kind, role="decode")
    router = Router([pre, dec])
    reqs = _reqs(3)
    for r in reqs:
        router.submit(r)
    done = router.run_until_done()
    assert all(r.done for r in reqs)
    assert len(done) == len(reqs)
    assert _streams(reqs) == _ref_streams(kind)
    m = router.metrics()
    assert m["handoffs"] == 3           # the 1-token req never ships
    assert m["handoffs_out"] == 3
    assert m["per_engine"][0]["decoded_tokens"] == 0
    assert m["per_engine"][1]["decoded_tokens"] > 0
    # parked time at the handoff is excluded from throughput/TTFT math
    for r in reqs:
        assert r.ttft_s is not None and r.ttft_s >= 0
        assert r.tokens_per_s is not None and r.tokens_per_s > 0


def test_disagg_prefill_keeps_slots_free():
    """A prefill-role engine pauses at admit: it never takes a slot and
    its handoff queue drains through withdraw_handoff in swap order."""
    pre = _engine("gdn", role="prefill")
    reqs = _reqs(2)
    for r in reqs:
        pre.submit(r)
    for _ in range(200):
        pre.step()
        if pre.handoffs == 2 and reqs[2].done:
            break
    assert pre.handoffs == 2
    assert pre.free_slots == pre.max_slots
    assert reqs[2].done                 # admit-boundary finisher
    rids = [pre.withdraw_handoff().req.rid for _ in range(2)]
    assert rids == [0, 1]
    assert pre.withdraw_handoff() is None
    assert pre.handoffs_out == 2


# ======================================================================
# process-boundary engines (EngineWorker subprocesses)
# ======================================================================
@pytest.mark.subprocess
def test_rpc_disagg_parity():
    """Two real worker processes (prefill + decode), weights shipped as
    the init seed: streams bitwise the in-process reference, handoffs
    cross the pipe, timing stamps survive, shutdown is clean."""
    cfg, _ = _model("gdn")
    kw = dict(max_slots=2, max_len=64, decode_block=2, prefill_chunk=8)
    pre = EngineProxy(cfg, params_seed=0, role="prefill", **kw)
    dec = EngineProxy(cfg, params_seed=0, role="decode", **kw)
    try:
        assert (pre.role, dec.role) == ("prefill", "decode")
        assert pre.max_len == 64 and pre.max_slots == 2
        router = Router([pre, dec])
        reqs = _reqs(3)
        for r in reqs:
            router.submit(r)
        router.run_until_done()
        assert all(r.done for r in reqs)
        assert _streams(reqs) == _ref_streams("gdn")
        m = router.metrics()
        assert m["handoffs"] == 3
        assert m["per_engine"][0]["decoded_tokens"] == 0
        for r in reqs:
            assert r.ttft_s is not None and r.ttft_s > 0
            assert r.latency_s is not None and r.latency_s > 0
    finally:
        pre.shutdown()
        dec.shutdown()
    assert pre.proc.poll() is not None  # workers really exited
    assert dec.proc.poll() is not None


@pytest.mark.subprocess
def test_rpc_worker_death_rehomes_queued():
    """Killing a worker mid-run: the router detects EOF on the channel,
    marks the engine dead, re-homes its still-queued requests to the
    surviving engine and finishes them; requests whose state lived in
    the dead process are failed, not hung."""
    cfg, params = _model("gdn")
    kw = dict(max_slots=2, max_len=64, decode_block=2, prefill_chunk=8)
    prox = EngineProxy(cfg, params_seed=0, **kw)
    local = _engine("gdn")
    router = Router([prox, local], policy="round_robin")
    reqs = _reqs(3)
    for r in reqs:
        router.submit(r)
    assert router.placed == [2, 2]
    prox.proc.kill()
    with pytest.warns(RuntimeWarning, match="worker died"):
        done = router.run_until_done()
    assert router.metrics()["dead"] == [0]
    assert router.rehomed == 2
    assert all(r.done for r in reqs)
    assert len(done) == len(reqs)
    # a dead proxy raises instead of hanging
    with pytest.raises(WorkerDied):
        prox.step()


@pytest.mark.subprocess
def test_rpc_worker_surfaces_engine_errors():
    """Engine-side exceptions cross the pipe as the matching exception
    type; the worker stays alive afterwards."""
    cfg, _ = _model("gdn")
    prox = EngineProxy(cfg, params_seed=0, role="decode", max_slots=2,
                       max_len=64, decode_block=2, prefill_chunk=8)
    try:
        with pytest.raises(ValueError, match="decode"):
            prox.submit(Request(rid=0,
                                prompt=np.arange(4, dtype=np.int32)))
        assert not prox.dead
        prox.step()                     # still serving
        assert prox.metrics()["role"] == "decode"
    finally:
        prox.shutdown()


# ======================================================================
# acceptance-adaptive k_draft
# ======================================================================
def _spec_engine(kind, *, adversarial, **kw):
    cfg, params = _model(kind)
    if adversarial:
        # random re-init: proposes junk the verify rejects (~1/vocab
        # acceptance) — the draft model a deployment must survive
        kw["draft_cfg"] = cfg
        kw["draft_params"] = lm.init_lm(jax.random.PRNGKey(99), cfg)
    return _engine(kind, speculative=True, k_draft=4, adaptive_k=True,
                   **kw)


def test_adaptive_k_self_draft_stays_max():
    eng = _spec_engine("gdn", adversarial=False)
    reqs = [Request(rid=i, prompt=np.arange(1, 7, dtype=np.int32),
                    max_new_tokens=24) for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    m = eng.metrics()
    assert m["adaptive_k"] == 1
    assert m["k_draft_effective"] == 4, (
        f"self-draft acceptance {m['acceptance_rate']:.2f} must keep "
        f"k at max, got {m['k_draft_effective']}")
    assert m["acceptance_rate"] > 0.8


def test_adaptive_k_collapses_under_adversarial_draft():
    """Acceptance collapse drives the effective k to 1 — and the
    emitted stream is still the non-speculative one (the shared-key
    verify never emits a wrong token, it just wastes drafts)."""
    base = _engine("gdn")
    ref = Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                  max_new_tokens=24)
    base.submit(ref)
    base.run_until_done()

    eng = _spec_engine("gdn", adversarial=True)
    req = Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                  max_new_tokens=24)
    eng.submit(req)
    eng.run_until_done()
    m = eng.metrics()
    assert m["k_draft_effective"] == 1, (
        f"acceptance {m['acceptance_rate']:.2f} must collapse k to 1, "
        f"got {m['k_draft_effective']}")
    assert m["acceptance_rate"] < 0.5
    assert list(req.output) == list(ref.output)


def test_adaptive_k_off_by_default():
    eng = _engine("gdn", speculative=True, k_draft=4)
    assert eng.adaptive_k is False
    assert eng.metrics()["adaptive_k"] == 0
    assert eng.metrics()["k_draft_effective"] == 4
