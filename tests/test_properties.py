"""Property-based tests (hypothesis) on system invariants."""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gdn
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=list(hypothesis.HealthCheck))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([8, 16, 32, 64, 128]),
    g=st.floats(0.0, 1.0),
    beta=st.floats(0.0, 1.0),
)
def test_fused_equals_naive_property(seed, d, g, beta):
    """Alg. 2 == Alg. 1 for any state, any gate values in [0, 1]."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (d,))
    k = jax.random.normal(ks[1], (d,))
    v = jax.random.normal(ks[2], (d,))
    S = jax.random.normal(ks[3], (d, d))
    o1, S1 = gdn.decode_step_naive(q, k, v, S, jnp.float32(g),
                                   jnp.float32(beta))
    o2, S2 = gdn.decode_step_fused(q, k, v, S, jnp.float32(g),
                                   jnp.float32(beta))
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S1, S2, rtol=1e-4, atol=1e-4)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    n_chunks=st.integers(1, 4),
    chunk=st.sampled_from([4, 8, 16]),
    delta_rule=st.booleans(),
)
def test_chunkwise_invariant_to_chunking(seed, n_chunks, chunk, delta_rule):
    """Chunk size is a pure performance knob — results must not change."""
    T, d = n_chunks * 16, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (T, d))
    k = jax.random.normal(ks[1], (T, d))
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    v = jax.random.normal(ks[2], (T, d))
    log_g = -jax.nn.softplus(jax.random.normal(ks[3], (T,)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[4], (T,)))
    S0 = jax.random.normal(ks[5], (d, d)) * 0.1
    O_a, S_a = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=chunk,
                                     delta_rule=delta_rule)
    O_b, S_b = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=T,
                                     delta_rule=delta_rule)
    np.testing.assert_allclose(O_a, O_b, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_a, S_b, rtol=5e-4, atol=5e-4)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_state_norm_bounded(seed):
    """With L2-normalized keys and g, beta in (0,1) the GDN state update is
    non-expansive in the key direction: retrieval error decays."""
    d = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    k = jax.random.normal(ks[0], (d,))
    k = k / jnp.linalg.norm(k)
    v = jax.random.normal(ks[1], (d,))
    S = jax.random.normal(ks[2], (d, d))
    beta = jnp.float32(0.9)
    # repeated writes of the same (k, v) converge S^T k -> v (g=1)
    err_prev = jnp.inf
    for _ in range(5):
        _, S = gdn.decode_step_fused(k, k, v, S, jnp.float32(1.0), beta)
        err = float(jnp.linalg.norm(S.T @ k - v))
        assert err <= err_prev * (1 + 1e-5)
        err_prev = err
    assert err_prev < 1e-2


@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=list(hypothesis.HealthCheck))
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    hb=st.sampled_from([2, 4]),   # must be a multiple of the GVA ratio (R=2)
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_kernel_matches_ref_property(seed, hb, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    B, Hk, Hv, d = 1, 2, 4, 32
    q = jax.random.normal(ks[0], (B, Hk, d)).astype(dt)
    k = jax.random.normal(ks[1], (B, Hk, d)).astype(dt)
    v = jax.random.normal(ks[2], (B, Hv, d)).astype(dt)
    S = (jax.random.normal(ks[3], (B, Hv, d, d)) * 0.2)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, Hv)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[5], (B, Hv)))
    o, S_new = ops.gdn_decode(q, k, v, S, g, beta, head_block=hb)
    o_r, S_r = ref.gdn_decode_ref(q, k, v, S, g, beta)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_r.astype(jnp.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(S_new, S_r, rtol=tol, atol=tol)


@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=list(hypothesis.HealthCheck))
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.1, 1.0),
)
def test_attn_decode_length_property(seed, frac):
    """Output must only depend on cache[:length] (masking correctness)."""
    B, Hq, Hkv, T, d = 1, 4, 2, 256, 32
    length = jnp.array([max(1, int(frac * T))], jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, Hkv, T, d))
    vc = jax.random.normal(ks[2], (B, Hkv, T, d))
    o1 = ops.attn_decode(q, kc, vc, length, block_t=64)
    # scribble beyond `length` — result must be identical
    noise = jax.random.normal(ks[3], (B, Hkv, T, d)) * 100
    mask = (jnp.arange(T) >= length[0])[None, None, :, None]
    kc2 = jnp.where(mask, noise, kc)
    vc2 = jnp.where(mask, noise, vc)
    o2 = ops.attn_decode(q, kc2, vc2, length, block_t=64)
    np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)
