"""Property-based tests (hypothesis) on system invariants."""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gdn
from repro.kernels import ops, ref

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=list(hypothesis.HealthCheck))


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    d=st.sampled_from([8, 16, 32, 64, 128]),
    g=st.floats(0.0, 1.0),
    beta=st.floats(0.0, 1.0),
)
def test_fused_equals_naive_property(seed, d, g, beta):
    """Alg. 2 == Alg. 1 for any state, any gate values in [0, 1]."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (d,))
    k = jax.random.normal(ks[1], (d,))
    v = jax.random.normal(ks[2], (d,))
    S = jax.random.normal(ks[3], (d, d))
    o1, S1 = gdn.decode_step_naive(q, k, v, S, jnp.float32(g),
                                   jnp.float32(beta))
    o2, S2 = gdn.decode_step_fused(q, k, v, S, jnp.float32(g),
                                   jnp.float32(beta))
    np.testing.assert_allclose(o1, o2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(S1, S2, rtol=1e-4, atol=1e-4)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    n_chunks=st.integers(1, 4),
    chunk=st.sampled_from([4, 8, 16]),
    delta_rule=st.booleans(),
)
def test_chunkwise_invariant_to_chunking(seed, n_chunks, chunk, delta_rule):
    """Chunk size is a pure performance knob — results must not change."""
    T, d = n_chunks * 16, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (T, d))
    k = jax.random.normal(ks[1], (T, d))
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    v = jax.random.normal(ks[2], (T, d))
    log_g = -jax.nn.softplus(jax.random.normal(ks[3], (T,)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[4], (T,)))
    S0 = jax.random.normal(ks[5], (d, d)) * 0.1
    O_a, S_a = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=chunk,
                                     delta_rule=delta_rule)
    O_b, S_b = gdn.prefill_chunkwise(q, k, v, log_g, beta, S0, chunk=T,
                                     delta_rule=delta_rule)
    np.testing.assert_allclose(O_a, O_b, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(S_a, S_b, rtol=5e-4, atol=5e-4)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(seed=st.integers(0, 2**31 - 1))
def test_state_norm_bounded(seed):
    """With L2-normalized keys and g, beta in (0,1) the GDN state update is
    non-expansive in the key direction: retrieval error decays."""
    d = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    k = jax.random.normal(ks[0], (d,))
    k = k / jnp.linalg.norm(k)
    v = jax.random.normal(ks[1], (d,))
    S = jax.random.normal(ks[2], (d, d))
    beta = jnp.float32(0.9)
    # repeated writes of the same (k, v) converge S^T k -> v (g=1)
    err_prev = jnp.inf
    for _ in range(5):
        _, S = gdn.decode_step_fused(k, k, v, S, jnp.float32(1.0), beta)
        err = float(jnp.linalg.norm(S.T @ k - v))
        assert err <= err_prev * (1 + 1e-5)
        err_prev = err
    assert err_prev < 1e-2


@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=list(hypothesis.HealthCheck))
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    hb=st.sampled_from([2, 4]),   # must be a multiple of the GVA ratio (R=2)
    dtype=st.sampled_from(["float32", "bfloat16"]),
)
def test_kernel_matches_ref_property(seed, hb, dtype):
    dt = jnp.dtype(dtype)
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    B, Hk, Hv, d = 1, 2, 4, 32
    q = jax.random.normal(ks[0], (B, Hk, d)).astype(dt)
    k = jax.random.normal(ks[1], (B, Hk, d)).astype(dt)
    v = jax.random.normal(ks[2], (B, Hv, d)).astype(dt)
    S = (jax.random.normal(ks[3], (B, Hv, d, d)) * 0.2)
    g = jax.nn.sigmoid(jax.random.normal(ks[4], (B, Hv)))
    beta = jax.nn.sigmoid(jax.random.normal(ks[5], (B, Hv)))
    o, S_new = ops.gdn_decode(q, k, v, S, g, beta, head_block=hb)
    o_r, S_r = ref.gdn_decode_ref(q, k, v, S, g, beta)
    tol = 3e-2 if dt == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(o.astype(jnp.float32),
                               o_r.astype(jnp.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(S_new, S_r, rtol=tol, atol=tol)


@hypothesis.settings(max_examples=10, deadline=None,
                     suppress_health_check=list(hypothesis.HealthCheck))
@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.1, 1.0),
)
def test_attn_decode_length_property(seed, frac):
    """Output must only depend on cache[:length] (masking correctness)."""
    B, Hq, Hkv, T, d = 1, 4, 2, 256, 32
    length = jnp.array([max(1, int(frac * T))], jnp.int32)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, d))
    kc = jax.random.normal(ks[1], (B, Hkv, T, d))
    vc = jax.random.normal(ks[2], (B, Hkv, T, d))
    o1 = ops.attn_decode(q, kc, vc, length, block_t=64)
    # scribble beyond `length` — result must be identical
    noise = jax.random.normal(ks[3], (B, Hkv, T, d)) * 100
    mask = (jnp.arange(T) >= length[0])[None, None, :, None]
    kc2 = jnp.where(mask, noise, kc)
    vc2 = jnp.where(mask, noise, vc)
    o2 = ops.attn_decode(q, kc2, vc2, length, block_t=64)
    np.testing.assert_allclose(o1, o2, rtol=1e-6, atol=1e-6)


# ------------------------------------ serving paging lifecycle (stateful)

stateful = pytest.importorskip("hypothesis.stateful")
import itertools

from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request

_PAGING = {}


def _paging_engine():
    """One shared engine for every stateful example: jit caches key on
    per-engine closures, so a fresh engine per example would recompile
    every program dozens of times over.  Each example starts by draining
    whatever the previous one left behind."""
    if "eng" not in _PAGING:
        cfg = configs.get_arch("qwen3-next-gdn").reduced()
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        # async paging with a deliberately tight 2-deep gather ring: the
        # random interleavings then exercise background drains, forced
        # harvests under ring pressure, prefetches and cancellations —
        # the sync path's values are identical by construction and are
        # pinned per-kind in tests/test_state_paging.py
        _PAGING["eng"] = DecodeEngine(cfg, params, max_slots=2,
                                      max_len=32, decode_block=2,
                                      prefill_chunk=8, staging_depth=2,
                                      async_paging=True, gather_ring=2)
        _PAGING["rid"] = itertools.count()
    return _PAGING["eng"], _PAGING["rid"]


class PagingLifecycleMachine(stateful.RuleBasedStateMachine):
    """Random submit/step/pause/resume/preempt interleavings must keep
    the oversubscribed scheduler's bookkeeping sound:

      * every slot is singly occupied (active ∪ free partitions slots);
      * every live request has exactly ONE home — queue, staging ring,
        a slot, or the swap store — never zero, never two;
      * swapped rids are disjoint from everything device-resident, and
        the resume queue is a duplicate-free subset of the swap store;
      * the resume queue is FIFO: grants only ever pop the oldest claim
        (the engine's queue is always a suffix of the order claims were
        filed);
      * async paging keeps its ledgers sound under random harvest /
        prefetch / cancel interleavings: a draining gather buffer is
        never reused before harvest (free tickets and pending tickets
        partition the ring), a prefetched image only ever belongs to a
        filed resume claim (cancelling the claim drops the prefetch),
        and swapped ∩ device = ∅ holds at every harvest boundary;
      * no request is lost or duplicated: once everything parked is
        reconnected, every submitted request finishes exactly once."""

    def __init__(self):
        super().__init__()
        self.eng, self.rids = _paging_engine()
        self._drain_previous()
        self.submitted = []
        self.resume_order = []

    def _drain_previous(self):
        eng = self.eng
        for s in eng._stagings:
            s.pause_pending = False
        for _ in range(300):
            for rid in list(eng.swapped):
                if rid not in eng.resume_q:
                    eng.resume(rid)
            if not (eng.queue or eng.active or eng._stagings
                    or eng.resume_q or eng.swapped):
                return
            eng.step()
        raise AssertionError("engine did not drain between examples")

    def _dormant(self):
        return [rid for rid in self.eng.swapped
                if rid not in self.eng.resume_q]

    # -------------------------------------------------------------- rules
    @stateful.rule(n_prompt=st.integers(2, 9), budget=st.integers(1, 6))
    def submit(self, n_prompt, budget):
        if sum(1 for r in self.eng._all if not r.done) >= 8:
            return                          # bound the live population
        req = Request(rid=next(self.rids),
                      prompt=np.arange(1, n_prompt + 1, dtype=np.int32),
                      max_new_tokens=budget)
        self.eng.submit(req)
        self.submitted.append(req)

    @stateful.rule()
    def step(self):
        self.eng.step()

    @stateful.rule(data=st.data())
    def pause(self, data):
        dormant = set(self._dormant())
        live = [r for r in self.eng._all
                if not r.done and r.rid not in dormant]
        if not live:
            return
        rid = data.draw(st.sampled_from([r.rid for r in live]),
                        label="pause rid")
        if rid in self.resume_order:        # resuming -> back to dormant
            self.resume_order.remove(rid)
        self.eng.pause(rid)

    @stateful.rule(data=st.data())
    def resume(self, data):
        dormant = self._dormant()
        if not dormant:
            return
        rid = data.draw(st.sampled_from(sorted(dormant)),
                        label="resume rid")
        self.eng.resume(rid)
        if rid in self.eng.resume_q:        # image-backed: files a claim
            self.resume_order.append(rid)

    @stateful.rule()
    def preempt(self):
        req = self.eng.preempt()
        if req is not None:
            self.resume_order.append(req.rid)

    @stateful.rule()
    def harvest(self):
        """Force every in-flight D2H drain to completion right now —
        a harvest boundary at an arbitrary point in the interleaving."""
        self.eng.flush_swaps()

    @stateful.rule()
    def prefetch(self):
        """Run the prestage policy outside its usual tick position."""
        self.eng._prefetch_resume()

    # --------------------------------------------------------- invariants
    @stateful.invariant()
    def slots_singly_occupied(self):
        eng = self.eng
        assert not set(eng.active) & set(eng.free)
        assert len(set(eng.free)) == len(eng.free)
        assert len(eng.active) + len(eng.free) == eng.max_slots

    @stateful.invariant()
    def one_home_per_live_request(self):
        eng = self.eng
        homes = ([id(r) for r in eng.queue]
                 + [id(s.req) for s in eng._stagings]
                 + [id(r) for r in eng.active.values()]
                 + [id(rec.req) for rec in eng.swapped.values()])
        assert len(homes) == len(set(homes)), "request in two structures"
        assert set(homes) == {id(r) for r in eng._all if not r.done}, \
            "live request lost (or a done one retained)"

    @stateful.invariant()
    def swapped_disjoint_from_device(self):
        eng = self.eng
        swapped = set(eng.swapped)
        assert swapped.isdisjoint(r.rid for r in eng.active.values())
        assert swapped.isdisjoint(s.req.rid for s in eng._stagings)
        assert swapped.isdisjoint(r.rid for r in eng.queue)
        assert set(eng.resume_q) <= swapped
        assert len(set(eng.resume_q)) == len(eng.resume_q)

    @stateful.invariant()
    def gather_ring_never_reused_before_harvest(self):
        eng, ex = self.eng, self.eng.executor
        free = list(ex._gather_free)
        pending = set(ex._gather_pending)
        assert len(set(free)) == len(free), "free ticket duplicated"
        assert not set(free) & pending, "draining buffer handed out"
        assert set(free) | pending == set(range(ex.gather_ring)), \
            "gather ticket lost"
        draining = {rid for rid, rec in eng.swapped.items()
                    if rec.pending is not None}
        assert set(eng._draining_q) == draining
        assert len(set(eng._draining_q)) == len(eng._draining_q)
        for rid in draining:
            rec = eng.swapped[rid]
            assert ex._gather_pending.get(rec.pending.buf) is rec.pending, \
                "pending swap not registered under its ring ticket"
            assert rec.state is None, "harvested record still marked draining"

    @stateful.invariant()
    def prefetch_only_backs_filed_claims(self):
        eng = self.eng
        for rid, rec in eng.swapped.items():
            if rec.prefetch is not None:
                assert rid in eng.resume_q, \
                    "prefetched image survived a cancelled resume"
                assert rec.pending is None and rec.state is not None, \
                    "prefetch staged from an unharvested image"

    @stateful.invariant()
    def resume_queue_is_fifo(self):
        rq = list(self.eng.resume_q)
        tail = self.resume_order[len(self.resume_order) - len(rq):] \
            if rq else []
        assert rq == tail, "resume grants must pop oldest-first"
        self.resume_order = rq

    def teardown(self):
        self._drain_previous()
        ex = self.eng.executor
        assert not ex._gather_pending and \
            len(ex._gather_free) == ex.gather_ring, \
            "gather tickets leaked across the example"
        for req in self.submitted:
            assert req.done, f"req {req.rid} lost"
            assert 1 <= len(req.output) <= req.max_new_tokens


PagingLifecycleMachine.TestCase.settings = hypothesis.settings(
    max_examples=12, stateful_step_count=25, deadline=None,
    suppress_health_check=list(hypothesis.HealthCheck))
test_serving_paging_lifecycle = PagingLifecycleMachine.TestCase
