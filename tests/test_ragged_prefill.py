"""Ragged chunked prefill: per-token validity masks, kernels → executor.

The masked planner replaces the pow2 tail-program family with ONE
fixed-size masked chunk; every guarantee that replacement rests on is
pinned here, bottom-up:

  * kernel parity — interpret-mode Pallas ``gdn_prefill`` with a ragged
    ``valid_len`` equals the sequential (token-by-token) oracle over the
    valid prefix, for both the delta-rule (gdn) and SSD (ssm) updates,
    and masked ``attn_prefill_chunk`` equals serial ``attn_decode_xla``
    including the rolling-window wrap at the valid/invalid boundary;
  * model parity — ``lm.prefill_chunk`` with valid_len leaves the caches
    of every mixer kind exactly as the unpadded chunk does (conv carries
    included), and a valid_len=0 chunk is a bitwise no-op;
  * engine parity — masked-planner token streams are identical to the
    pow2-planner baseline (greedy and stochastic, overlapped and
    serialized) across all five mixer kinds;
  * the compile-cache claim — at most 2 distinct prefill program shapes
    dispatched per prompt length, observable via the new
    ``compiled_programs`` counter.

The CI kernel-path job re-runs this module with REPRO_PALLAS_SERVING=1
so the Pallas prefill/decode paths (interpret mode on CPU) are exercised
per PR.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import gdn as gdn_core
from repro.models import attention, layers, lm
from repro.serving.engine import DecodeEngine, Request

# one arch per mixer family; gdn_naive shares gdn's prefill path but is
# pinned at the engine level below
ARCHS = {
    "gdn": "qwen3-next-gdn",
    "ssm": "mamba2-1.3b",
    "rglru": "recurrentgemma-2b",
    "attn": "yi-9b",
    "swa": "h2o-danube-1.8b",
}


def _arch_cfg(name):
    cfg = configs.get_arch(name).reduced()
    if os.environ.get("REPRO_PALLAS_SERVING") == "1":
        cfg = cfg.replace(use_pallas_serving=True)
    return cfg


# ------------------------------------------------------- kernel parity

@pytest.mark.parametrize("delta_rule", [True, False],
                         ids=["gdn", "ssd"])
@pytest.mark.parametrize("valid", [3, 5, 11, 16])
def test_gdn_prefill_kernel_masked_matches_serial(delta_rule, valid):
    """Interpret-mode Pallas gdn_prefill with ragged valid_len == the
    sequential decode oracle over the valid prefix: the state is provably
    unchanged by padding (k/v/beta columns and log-gate contributions are
    zeroed inside the kernel)."""
    from repro.kernels.gdn_prefill import gdn_prefill_pallas
    rng = np.random.default_rng(0)
    BH, T, dk, dv, C = 3, 16, 8, 8, 4
    q = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(BH, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(BH, T, dv)), jnp.float32)
    lg = jnp.asarray(-np.abs(rng.normal(size=(BH, T))), jnp.float32)
    b = jax.nn.sigmoid(jnp.asarray(rng.normal(size=(BH, T)), jnp.float32))
    S0 = jnp.asarray(rng.normal(size=(BH, dk, dv)), jnp.float32)

    O, S = gdn_prefill_pallas(q, k, v, lg, b, S0,
                              jnp.full((BH,), valid, jnp.int32),
                              chunk=C, delta_rule=delta_rule,
                              interpret=True)
    for h in range(BH):
        Oref, Sref = gdn_core.prefill_sequential(
            q[h, :valid], k[h, :valid], v[h, :valid], lg[h, :valid],
            b[h, :valid], S0[h], delta_rule=delta_rule)
        np.testing.assert_allclose(np.asarray(O[h, :valid]),
                                   np.asarray(Oref), rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(S[h]), np.asarray(Sref),
                                   rtol=2e-5, atol=2e-5)


def test_gdn_prefill_kernel_all_valid_bitwise():
    """valid_len == T reproduces the unmasked kernel bit-for-bit (the
    masking is where(True, x, 0) — the identity)."""
    from repro.kernels.gdn_prefill import gdn_prefill_pallas
    rng = np.random.default_rng(1)
    BH, T, dk, dv = 2, 8, 8, 8
    args = [jnp.asarray(rng.normal(size=s), jnp.float32) for s in
            ((BH, T, dk), (BH, T, dk), (BH, T, dv))]
    lg = jnp.asarray(-np.abs(rng.normal(size=(BH, T))), jnp.float32)
    b = jax.nn.sigmoid(jnp.asarray(rng.normal(size=(BH, T)), jnp.float32))
    S0 = jnp.asarray(rng.normal(size=(BH, dk, dv)), jnp.float32)
    O1, S1 = gdn_prefill_pallas(*args, lg, b, S0, None, chunk=4,
                                interpret=True)
    O2, S2 = gdn_prefill_pallas(*args, lg, b, S0,
                                jnp.full((BH,), T, jnp.int32), chunk=4,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(O1), np.asarray(O2))
    np.testing.assert_array_equal(np.asarray(S1), np.asarray(S2))


def test_flash_attn_ragged_masks_keys_and_grads():
    """flash_attention with valid_len: valid output rows equal the dense
    softmax over the valid prefix, and dk/dv rows at padded positions
    vanish when the loss masks padded outputs (the kernel's score mask
    keeps padding out of the accumulations)."""
    from repro.kernels.flash_attn import flash_attention
    rng = np.random.default_rng(2)
    B, T, Hq, Hkv, hd = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, Hq, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, hd)), jnp.float32)
    vl = jnp.asarray([37, 64], jnp.int32)

    o = flash_attention(q, k, v, 32, 32, None, True, vl)
    for i, L in enumerate([37, 64]):
        qg = q[i, :L].reshape(L, Hkv, Hq // Hkv, hd)
        s = jnp.einsum("thgd,shd->thgs", qg, k[i, :L]) / np.sqrt(hd)
        mask = np.tril(np.ones((L, L), bool))
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        od = jnp.einsum("thgs,shd->thgd", jax.nn.softmax(s, -1),
                        v[i, :L]).reshape(L, Hq, hd)
        np.testing.assert_allclose(np.asarray(o[i, :L]), np.asarray(od),
                                   rtol=2e-5, atol=2e-5)

    def loss(q, k, v):
        o = flash_attention(q, k, v, 32, 32, None, True, vl)
        m = (jnp.arange(T)[None, :, None, None]
             < vl[:, None, None, None]).astype(o.dtype)
        return jnp.sum((o * m) ** 2)

    dq, dk, dv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(np.asarray(dk[0, 37:]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(dv[0, 37:]), 0.0, atol=1e-6)
    assert np.isfinite(np.asarray(dq)).all()


def test_attn_decode_kernel_owns_occupancy_clamp():
    """ops.attn_decode accepts the raw token count (> buffer size in the
    rolling phase) and clamps the occupancy mask in-kernel — callers no
    longer pre-clamp."""
    from repro.kernels import ops
    rng = np.random.default_rng(3)
    B, Hkv, Hq, T, hd = 2, 2, 4, 8, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)), jnp.float32)
    raw = jnp.asarray([5, 21], jnp.int32)            # 21 > T: rolling
    clamped = jnp.minimum(raw, T)
    o_raw = ops.attn_decode(q, kc, vc, raw, block_t=4, interpret=True)
    o_cl = ops.attn_decode(q, kc, vc, clamped, block_t=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_raw), np.asarray(o_cl))


def test_attn_decode_window_masks_absolute_positions():
    """The in-kernel window mask compares *absolute* positions: in the
    rolling phase the newest tokens wrap onto the lowest slots, so a
    window < buffer must keep exactly the slots holding positions
    >= length - window (slot index order is rotated)."""
    from repro.kernels import ops
    rng = np.random.default_rng(4)
    B, Hkv, Hq, T, hd, window = 1, 1, 2, 8, 16, 4
    q = jnp.asarray(rng.normal(size=(B, Hq, hd)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, Hkv, T, hd)), jnp.float32)
    length = 12                                      # rolling: 12 > 8
    o = ops.attn_decode(q, kc, vc, jnp.asarray([length], jnp.int32),
                        block_t=4, window=window, interpret=True)
    # oracle: slot t holds position (length-1) - ((length-1-t) mod T);
    # visible iff that position >= length - window -> slots 0..3 here
    p_abs = (length - 1) - np.mod(length - 1 - np.arange(T), T)
    vis = p_abs >= length - window
    assert list(np.nonzero(vis)[0]) == [0, 1, 2, 3]
    s = np.einsum("hd,td->ht", np.asarray(q[0]),
                  np.asarray(kc[0, 0])) / np.sqrt(hd)
    s = np.where(vis[None, :], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    oracle = np.einsum("ht,td->hd", p, np.asarray(vc[0, 0]))
    np.testing.assert_allclose(np.asarray(o[0]), oracle,
                               rtol=2e-5, atol=2e-5)


# ----------------------------------------- attn chunk rolling boundary

def test_masked_attn_chunk_matches_serial_decode_at_wrap():
    """Masked attn_prefill_chunk == serial decode when the valid/invalid
    boundary lands mid-wrap of the rolling buffer: padded positions must
    not be inserted (their wrapped slot aliases a still-visible valid
    token) and length must advance by valid_len only."""
    cfg = _arch_cfg(ARCHS["swa"])
    key = jax.random.PRNGKey(0)
    p = attention.init_attention(key, cfg.d_model, cfg.hq_eff,
                                 cfg.hkv_eff, cfg.head_dim)
    size = 8                                         # small rolling buffer
    B, C = 1, 6
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 13, cfg.d_model),
                          jnp.float32)

    def fresh():
        kv = jnp.zeros((B, cfg.hkv_eff, size, cfg.head_dim), jnp.float32)
        return attention.KVCache(kv, kv, jnp.zeros((B,), jnp.int32))

    # serial: 7 pre-chunk tokens (buffer about to wrap), then 4 more
    serial = fresh()
    outs = []
    for t in range(11):
        o, serial = attention.attn_decode_xla(p, x[:, t], serial,
                                              window=size)
        outs.append(o)

    # chunked: 7 tokens via an exact chunk + a ragged chunk of 4-of-6,
    # whose padded positions would wrap onto slots 3, 4 if inserted
    chunked = fresh()
    _, chunked = attention.attn_prefill_chunk(p, x[:, :7], chunked,
                                              window=size)
    out, chunked = attention.attn_prefill_chunk(
        p, x[:, 7:13], chunked, window=size, valid_len=jnp.int32(4))
    assert int(chunked.length[0]) == 11
    np.testing.assert_allclose(np.asarray(out[:, :4]),
                               np.asarray(jnp.stack(outs[7:], 1)),
                               rtol=2e-4, atol=2e-4)
    for a, b in zip(jax.tree.leaves(chunked), jax.tree.leaves(serial)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-4, atol=2e-4)


# -------------------------------------------------- lm-level parity

@pytest.mark.parametrize("kind", sorted(ARCHS))
def test_lm_masked_chunk_matches_exact(kind):
    """lm.prefill_chunk with valid_len leaves every cache leaf as the
    unpadded chunk does — for each mixer family, across a rolling wrap —
    and a valid_len=0 chunk is a bitwise no-op."""
    cfg = _arch_cfg(ARCHS[kind])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    max_len, T = 16, 21
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, T), 1, cfg.vocab)

    exact = lm.init_caches(cfg, 1, max_len)
    pos = 0
    for s in (8, 8, 5):
        x, exact = lm.prefill_chunk(params, cfg, exact,
                                    tokens=tokens[:, pos:pos + s])
        pos += s

    masked = lm.init_caches(cfg, 1, max_len)
    for a, b in ((0, 8), (8, 16)):
        _, masked = lm.prefill_chunk(params, cfg, masked,
                                     tokens=tokens[:, a:b])
    pad = jnp.concatenate([tokens[:, 16:21],
                           jnp.zeros((1, 3), tokens.dtype)], 1)
    xm, masked = lm.prefill_chunk(params, cfg, masked, tokens=pad,
                                  valid_len=jnp.int32(5))
    np.testing.assert_allclose(np.asarray(xm[:, 4]), np.asarray(x[:, -1]),
                               rtol=2e-5, atol=2e-5)
    for a, b in zip(jax.tree.leaves(masked), jax.tree.leaves(exact)):
        if a.dtype.kind in "iub":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       rtol=2e-5, atol=2e-5)

    before = jax.tree.map(lambda a: np.asarray(a).copy(), masked)
    _, after = lm.prefill_chunk(params, cfg, masked,
                                tokens=jnp.zeros((1, 8), tokens.dtype),
                                valid_len=jnp.int32(0))
    for a, b in zip(jax.tree.leaves(after), jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(a), b)


def test_lm_chunk_scan_with_placeholder_chunks():
    """prefill_chunk_scan with a trailing valid_lens=0 placeholder chunk
    is a bitwise no-op relative to the masked scan without it (one scan
    shape covers any full-chunk count), and the masked scan agrees with
    the unmasked program to float-fusion tolerance (the where-masking
    changes XLA fusion order, never the math — stream-level identity is
    pinned by the engine parity tests below)."""
    cfg = _arch_cfg(ARCHS["gdn"])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    C, max_len = 8, 64
    tokens = jax.random.randint(jax.random.PRNGKey(2), (1, 2 * C), 1,
                                cfg.vocab)
    a = lm.prefill_chunk_scan(params, cfg, lm.init_caches(cfg, 1, max_len),
                              tokens=tokens.reshape(1, 2, C))
    padded = jnp.concatenate([tokens, jnp.zeros((1, C), tokens.dtype)], 1)
    b = lm.prefill_chunk_scan(params, cfg, lm.init_caches(cfg, 1, max_len),
                              tokens=padded.reshape(1, 3, C),
                              valid_lens=jnp.asarray([C, C, 0], jnp.int32))
    c = lm.prefill_chunk_scan(params, cfg, lm.init_caches(cfg, 1, max_len),
                              tokens=tokens.reshape(1, 2, C),
                              valid_lens=jnp.asarray([C, C], jnp.int32))
    for x, y in zip(jax.tree.leaves(c), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_base_mixer_rejects_ragged_chunks():
    """A registry kind that does not override prefill_chunk must reject
    masked chunks instead of silently folding padding into its state."""
    from repro.models.mixers.base import SequenceMixer

    class Stub(SequenceMixer):
        kind = "stub"

        @classmethod
        def prefill(cls, params, cfg, x, cache):   # pragma: no cover
            return x, cache

    assert not Stub.supports_ragged_prefill
    with pytest.raises(NotImplementedError, match="ragged"):
        Stub.prefill_chunk(None, None, None, None, valid_len=jnp.int32(1))


def test_executor_falls_back_to_pow2_for_unmasked_kinds(monkeypatch):
    """A pattern containing a kind without ragged-prefill support still
    serves under the default plan_mode: the executor warns and falls back
    to pow2 plans instead of corrupting state (the declarative
    ``supports_ragged_prefill`` capability gates the masked planner)."""
    from repro.models.mixers.gdn import GatedDeltaNet
    cfg = _arch_cfg(ARCHS["gdn"])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    monkeypatch.setattr(GatedDeltaNet, "supports_ragged_prefill", False)
    with pytest.warns(RuntimeWarning, match="falling back to "
                                           "plan_mode='pow2'"):
        eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                           decode_block=1, prefill_chunk=8)
    assert eng.plan_mode == "pow2"
    eng.submit(Request(rid=0, prompt=np.arange(1, 12, dtype=np.int32),
                       max_new_tokens=2))
    assert all(r.done for r in eng.run_until_done())


# ------------------------------------------------------ engine parity

def _serve(cfg, params, *, plan_mode, overlap=True, stochastic=False,
           prefill_chunk=8, n=5):
    eng = DecodeEngine(cfg, params, max_slots=2, max_len=64,
                       decode_block=4, overlap=overlap,
                       prefill_chunk=prefill_chunk, plan_mode=plan_mode)
    reqs = [Request(rid=i, prompt=np.arange(1, 7 + 5 * i, dtype=np.int32),
                    max_new_tokens=4 + i,
                    temperature=0.8 if stochastic else 0.0,
                    top_k=10 if stochastic else 0,
                    top_p=0.9 if stochastic else 1.0)
            for i in range(n)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return eng, [list(r.output) for r in reqs]


@pytest.mark.parametrize("kind", sorted(ARCHS) + ["gdn_naive"])
def test_masked_planner_streams_match_pow2(kind):
    """The tentpole guarantee: masked-planner token streams are identical
    to the pow2-planner baseline for every mixer kind, greedy AND
    stochastic — the plan shape is a pure compile-cache choice, never a
    sampling choice."""
    arch = ARCHS.get(kind, ARCHS["gdn"])
    cfg = _arch_cfg(arch)
    if kind == "gdn_naive":
        cfg = cfg.replace(pattern=tuple(
            "gdn_naive" if k == "gdn" else k for k in cfg.pattern))
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    e_pow2, s_pow2 = _serve(cfg, params, plan_mode="pow2")
    e_mask, s_mask = _serve(cfg, params, plan_mode="masked")
    assert s_mask == s_pow2
    # the compile-cache reduction is observable, not just claimed
    assert (e_mask.executor.compiled_programs()["prefill"]
            < e_pow2.executor.compiled_programs()["prefill"])
    _, st_pow2 = _serve(cfg, params, plan_mode="pow2", stochastic=True)
    _, st_mask = _serve(cfg, params, plan_mode="masked", stochastic=True)
    assert st_mask == st_pow2


def test_masked_serialized_matches_overlapped():
    cfg = _arch_cfg(ARCHS["gdn"])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    _, ovl = _serve(cfg, params, plan_mode="masked", overlap=True)
    _, ser = _serve(cfg, params, plan_mode="masked", overlap=False)
    assert ovl == ser


def test_at_most_two_prefill_shapes_per_prompt():
    """Serve one prompt per fresh engine across awkward lengths: the
    compiled_programs counter shows at most 2 prefill programs — the
    acceptance criterion of the masked planner."""
    cfg = _arch_cfg(ARCHS["gdn"])
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    for T in (1, 7, 8, 9, 23, 40, 41, 57):
        eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                           decode_block=1, prefill_chunk=8)
        eng.submit(Request(rid=0, prompt=np.arange(1, T + 1,
                                                   dtype=np.int32),
                           max_new_tokens=2))
        eng.run_until_done()
        progs = eng.executor.compiled_programs()
        assert progs["prefill"] <= 2, (T, progs)
        assert eng.metrics()["prefill_programs"] == progs["prefill"]
    # across ALL prompt lengths one engine stays at O(1) prefill shapes
    eng = DecodeEngine(cfg, params, max_slots=1, max_len=64,
                       decode_block=1, prefill_chunk=8)
    for rid, T in enumerate((1, 7, 8, 9, 23, 40, 41, 57)):
        eng.submit(Request(rid=rid, prompt=np.arange(1, T + 1,
                                                     dtype=np.int32),
                           max_new_tokens=2))
    eng.run_until_done()
    assert eng.executor.compiled_programs()["prefill"] <= 5
