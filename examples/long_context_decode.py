"""Long-context decode with O(1) state — the paper's regime at scale.

Decodes with a mamba2 (SSD) model far past any window/cache size: the
recurrent state is a fixed (heads, d_state, d_head) tensor per layer no
matter how long the context grows — contrast with the full-attention archs
whose KV cache would grow linearly (and which therefore skip the 500k cell,
see DESIGN.md).  Also demonstrates state-consistency: decoding T tokens
step-by-step equals one chunkwise prefill over the same tokens.

    PYTHONPATH=src python examples/long_context_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import lm


def main():
    cfg = configs.get_arch("mamba2-1.3b").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    B, T = 1, 48

    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0,
                                cfg.vocab)

    # (a) chunkwise prefill over T tokens, then one decode step
    caches = lm.init_caches(cfg, B, max_len=64)
    _, caches = lm.prefill(params, cfg, caches, tokens=tokens[:, :T])
    logits_a, _ = lm.decode_step(params, cfg, tokens[:, T], caches)

    # (b) pure decode: feed the same tokens one at a time
    caches_b = lm.init_caches(cfg, B, max_len=64)
    decode = jax.jit(lambda p, t, c: lm.decode_step(p, cfg, t, c),
                     donate_argnums=(2,))
    for t in range(T + 1):
        logits_b, caches_b = decode(params, tokens[:, t], caches_b)

    err = float(jnp.max(jnp.abs(logits_a - logits_b)))
    print(f"prefill+decode vs pure-decode max|dlogits| = {err:.2e}")
    assert err < 2e-2

    # state size is constant regardless of context length:
    state_bytes = sum(
        x.nbytes for x in jax.tree.leaves(caches_b))
    print(f"recurrent state/cache bytes: {state_bytes/1e3:.1f} KB "
          f"(constant in context length — the paper's enabling property)")


if __name__ == "__main__":
    main()
