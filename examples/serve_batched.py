"""End-to-end serving example: continuous batching with persistent state.

Eight requests stream through four decode slots of a hybrid GDN model.
Each layer's recurrent state lives in donated device buffers (the TPU
analogue of the paper's BRAM-resident state) and is updated in place by
the fused decode step every tick.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def main():
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, max_slots=4, max_len=96)

    rng = np.random.default_rng(7)
    requests = []
    for i in range(8):
        prompt = rng.integers(1, cfg.vocab, size=6 + i, dtype=np.int32)
        req = Request(rid=i, prompt=prompt, max_new_tokens=6 + (i % 3),
                      temperature=0.7 if i % 2 else 0.0)
        requests.append(req)
        engine.submit(req)

    t0 = time.perf_counter()
    done = engine.run_until_done()
    dt = time.perf_counter() - t0

    total = sum(len(r.output) for r in done)
    print(f"{len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({engine.ticks} batched ticks; continuous batching reused "
          f"{len(requests) - engine.max_slots} slots)")
    for r in requests:
        print(f"  req {r.rid} ({'greedy' if r.temperature == 0 else 'T=0.7'})"
              f": {r.output}")
    assert all(r.done for r in requests)


if __name__ == "__main__":
    main()
