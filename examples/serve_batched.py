"""End-to-end serving example: continuous batching with persistent state,
a device-resident decode hot loop, and overlapped chunked prefill.

Eight requests stream through four decode slots of a hybrid GDN model.
Each layer's recurrent state lives in donated device buffers (the TPU
analogue of the paper's BRAM-resident state) and is updated in place by
the fused decode step every tick.  Sampling (greedy and temperature /
top-k / top-p, per-slot) and the EOS / budget finished-flags also run on
device, and each tick fuses ``decode_block`` decode+sample steps into one
``lax.scan`` — the host syncs once per 4 tokens here, not once per token.
Queued prompts prefill in chunks into a staging buffer *between* decode
ticks (the scheduler/executor split), with the first token sampled on
device by the fused admit head, so admission never stalls the resident
slots and TTFT does not wait for a free slot.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serving.engine import DecodeEngine, Request


def main():
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    params = lm.init_lm(jax.random.PRNGKey(0), cfg)
    engine = DecodeEngine(cfg, params, max_slots=4, max_len=96,
                          decode_block=4, overlap=True, prefill_chunk=8)

    rng = np.random.default_rng(7)
    requests = []
    for i in range(8):
        prompt = rng.integers(1, cfg.vocab, size=6 + i, dtype=np.int32)
        req = Request(rid=i, prompt=prompt, max_new_tokens=6 + (i % 3),
                      temperature=0.7 if i % 2 else 0.0,
                      top_k=20 if i % 4 == 1 else 0,
                      top_p=0.9 if i % 4 == 3 else 1.0)
        requests.append(req)
        engine.submit(req)

    t0 = time.perf_counter()
    engine.run_until_done()
    dt = time.perf_counter() - t0

    m = engine.metrics()
    print(f"{m['requests']} requests / {m['tokens']} tokens in {dt:.2f}s "
          f"({m['ticks']} batched ticks x {engine.decode_block}-token "
          f"blocks; continuous batching reused "
          f"{len(requests) - engine.max_slots} slots)")
    print(f"decode hot loop: {m['decode_us_per_token']:.0f} us/token, "
          f"mean ttft {m['mean_ttft_s'] * 1e3:.0f} ms, "
          f"mean latency {m['mean_latency_s'] * 1e3:.0f} ms "
          f"({m['stage_dispatches']} staged prefill dispatches "
          f"overlapped with decode)")
    for r in requests:
        how = ("greedy" if r.temperature == 0 else
               f"T={r.temperature}" + (f",k={r.top_k}" if r.top_k else "")
               + (f",p={r.top_p}" if r.top_p < 1 else ""))
        print(f"  req {r.rid} ({how}): {r.output}")
    assert all(r.done for r in requests)


if __name__ == "__main__":
    main()
