"""Quickstart: the paper's primitive end to end in 60 lines.

1. Run one fused GDN decode step (paper Alg. 2) against the naive Alg. 1
   and show they agree while touching the state half as often.
2. Train a small Qwen3-Next-style hybrid (3:1 GDN:attention) for a few
   hundred steps on synthetic data and watch the loss drop.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import gdn
from repro import configs
from repro.runtime.trainer import Trainer, TrainerConfig


def decode_step_demo():
    print("== paper Alg. 1 vs Alg. 2 (one value-head, d=128) ==")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (128,))
    k = jax.random.normal(ks[1], (128,))
    k = k / jnp.linalg.norm(k)
    v = jax.random.normal(ks[2], (128,))
    S = jax.random.normal(ks[3], (128, 128)) * 0.1
    g, beta = jnp.float32(0.95), jnp.float32(0.8)

    o_naive, S_naive = gdn.decode_step_naive(q, k, v, S, g, beta)
    o_fused, S_fused = gdn.decode_step_fused(q, k, v, S, g, beta)
    print(f"  max|o_naive - o_fused|  = {jnp.max(jnp.abs(o_naive - o_fused)):.2e}")
    print(f"  max|S_naive - S_fused|  = {jnp.max(jnp.abs(S_naive - S_fused)):.2e}")
    print("  naive: 3 passes over S;  fused: 1 read + 1 write (Eq. 13)")


def train_demo(steps=300):
    print(f"\n== training qwen3-next-gdn (reduced) for {steps} steps ==")
    cfg = configs.get_arch("qwen3-next-gdn").reduced()
    tc = TrainerConfig(steps=steps, seq_len=64, global_batch=4,
                       peak_lr=3e-3, warmup_steps=20, log_every=50)
    trainer = Trainer(cfg, tc)
    history = trainer.run()
    for step, loss in history:
        print(f"  step {step:4d}  loss {loss:.3f}")
    assert history[-1][1] < history[0][1], "loss should decrease"
    print("  loss decreased — the gated delta rule is learning.")


if __name__ == "__main__":
    decode_step_demo()
    train_demo()
