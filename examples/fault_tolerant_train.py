"""Fault-tolerance drill: inject a node failure mid-training and watch the
runtime restore from the latest atomic checkpoint and finish the run —
then restart the whole process and verify it resumes (elastic restart).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""
import logging
import tempfile

from repro import configs
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    logging.basicConfig(level=logging.INFO, format="%(name)s: %(message)s")
    cfg = configs.get_arch("mamba2-1.3b").reduced()
    with tempfile.TemporaryDirectory() as d:
        tc = TrainerConfig(steps=12, seq_len=32, global_batch=2,
                           ckpt_dir=d, ckpt_every=4, ckpt_async=False,
                           log_every=4)
        print("== run 1: failure injected at step 6 ==")
        t1 = Trainer(cfg, tc)
        t1.run(fail_at=6)
        print(f"   restarts used: {t1.restarts} (recovered from step 4 "
              f"checkpoint, finished step {tc.steps})")

        print("== run 2: fresh process resumes from the final checkpoint ==")
        t2 = Trainer(cfg, tc)
        t2.compile()
        resumed = t2._maybe_restore()
        print(f"   resumed at step {resumed} — nothing left to do"
              if resumed == tc.steps else f"   resumed at {resumed}")
        assert resumed == tc.steps


if __name__ == "__main__":
    main()
